"""§5 client buffer under network delay/loss/jitter models (ISSUE 9 sat. 3).

Covers: identity link preserves existing timelines bit-exactly; in-order
(head-of-line) delivery; determinism of the seeded draws; pacing stays
smooth (no stall longer than the buffer target) under injected jitter once
the buffer has built a lead; QoE degrades monotonically with loss rate
(exact, via the monotone-coupled draws); the scenario catalog orders QoE
from clean to hostile links.
"""
import numpy as np
import pytest

from repro.core.network import (
    NETWORK_SCENARIOS,
    JitterLossLink,
    NetworkModel,
    make_network,
    qoe_under_network,
)
from repro.core.qoe import QoESpec, pace_delivery, qoe_exact
from repro.core.token_buffer import TokenBuffer

SPEC = QoESpec(ttft=1.0, tds=4.8)
# a stringent spec for degradation tests — with the default reading spec the
# buffer hides mild impairments entirely (QoE pins at 1.0), which is §5's
# point but leaves nothing to order
TIGHT = QoESpec(ttft=0.2, tds=6.0)


def steady_emits(n=40, rate=8.0, start=0.3):
    """Server emitting faster than the user's TDS (buffer builds a lead)."""
    return start + np.arange(n) / rate


# ---------------------------------------------------------------------------
# identity link + plumbing
# ---------------------------------------------------------------------------

def test_identity_link_is_transparent():
    e = steady_emits()
    net = NetworkModel()
    assert np.array_equal(net.arrivals(e), e)
    # pace_delivery(..., network=identity) == pace_delivery(...)
    assert np.array_equal(pace_delivery(e, SPEC.tds, network=NetworkModel()),
                          pace_delivery(e, SPEC.tds))


def test_token_buffer_network_default_unchanged():
    e = steady_emits(10)
    plain = TokenBuffer(SPEC.tds)
    netted = TokenBuffer(SPEC.tds, network=NetworkModel())
    for t in e:
        assert plain.push(t) == netted.push(t)
    assert plain.deliveries == netted.deliveries


def test_token_buffer_incremental_matches_vectorized():
    e = steady_emits(25)
    link = JitterLossLink(delay=0.05, jitter=0.03, loss=0.05, seed=7)
    buf = TokenBuffer(SPEC.tds, network=link.clone())
    inc = np.array([buf.push(t) for t in e])
    vec = pace_delivery(e, SPEC.tds, network=link.clone())
    np.testing.assert_allclose(inc, vec)


def test_in_order_delivery_head_of_line_blocks():
    # a huge one-off latency on token 3 must delay every later arrival
    class Spike(NetworkModel):
        def latency(self, i):
            return 5.0 if i == 3 else 0.0

    e = np.arange(10, dtype=float)
    arr = Spike().arrivals(e)
    assert np.all(np.diff(arr) >= 0.0)
    assert arr[3] == pytest.approx(e[3] + 5.0)
    # tokens 4..8 emitted before the spike clears: they queue behind it
    assert np.all(arr[4:9] == arr[3])
    assert arr[9] == pytest.approx(9.0)


def test_draws_deterministic_and_call_pattern_independent():
    a = JitterLossLink(delay=0.02, jitter=0.05, loss=0.1, seed=3)
    b = JitterLossLink(delay=0.02, jitter=0.05, loss=0.1, seed=3)
    # probe b out of order first — the per-index draws must not shift
    b.latency(17)
    lat_a = [a.latency(i) for i in range(20)]
    lat_b = [b.latency(i) for i in range(20)]
    assert lat_a == lat_b
    e = steady_emits()
    np.testing.assert_array_equal(a.arrivals(e), a.arrivals(e))


# ---------------------------------------------------------------------------
# smooth pacing under jitter (satellite requirement)
# ---------------------------------------------------------------------------

def test_pacing_smooth_under_jitter():
    """Once the buffer holds a lead, injected jitter must not surface as a
    user-visible stall: inter-display gaps never exceed the buffer target
    (1/tds), up to float slack."""
    e = steady_emits(n=60, rate=8.0)       # generation 8 tok/s > tds 4.8
    link = JitterLossLink(delay=0.03, jitter=0.04, seed=11)
    d = pace_delivery(e, SPEC.tds, network=link)
    gaps = np.diff(d)
    target = 1.0 / SPEC.tds
    # warmup: the first few tokens may arrive before any lead exists; the
    # generation-vs-tds surplus buys >= one jittered transit per token, so
    # by token 5 the lead dominates the jitter scale
    assert np.all(gaps[5:] <= target + 1e-9), (
        f"stall longer than buffer target: max gap {gaps[5:].max():.4f}s "
        f"vs target {target:.4f}s")
    # and pacing is exactly the target once smooth (buffer is withholding)
    assert np.all(gaps[5:] >= target - 1e-9)


def test_jitter_without_buffer_lead_does_stall():
    """Control for the test above: when generation is *slower* than the
    user's TDS there is no lead to absorb jitter, so stalls do appear —
    the smoothness in test_pacing_smooth_under_jitter is the buffer's
    doing, not an artifact of a tame link model."""
    e = steady_emits(n=40, rate=3.0)       # generation 3 tok/s < tds 4.8
    link = JitterLossLink(jitter=0.25, seed=11)
    d = pace_delivery(e, SPEC.tds, network=link)
    assert np.max(np.diff(d)) > 1.0 / SPEC.tds + 1e-9


# ---------------------------------------------------------------------------
# QoE degrades monotonically with loss (satellite requirement)
# ---------------------------------------------------------------------------

def test_qoe_monotone_in_loss():
    e = steady_emits(n=50, rate=6.0)
    losses = [0.0, 0.02, 0.05, 0.1, 0.2, 0.4]
    qoes = []
    for p in losses:
        link = JitterLossLink(delay=0.03, jitter=0.01, loss=p, rto=0.25,
                              seed=5)
        qoes.append(qoe_under_network(e, 0.0, TIGHT, network=link))
    # same seed => monotone-coupled draws => exact (not statistical) decay
    for lo, hi, q_lo, q_hi in zip(losses, losses[1:], qoes, qoes[1:]):
        assert q_hi <= q_lo + 1e-12, (
            f"QoE rose when loss went {lo} -> {hi}: {q_lo} -> {q_hi}")
    assert qoes[-1] < qoes[0]              # decay is strict overall


def test_latency_monotone_in_each_knob():
    base = dict(delay=0.02, jitter=0.03, loss=0.05, rto=0.2, seed=9)
    ref = JitterLossLink(**base)
    for knob, bump in [("delay", 0.05), ("jitter", 0.05), ("loss", 0.1),
                       ("rto", 0.3)]:
        worse = JitterLossLink(**{**base, knob: base[knob] + bump})
        for i in range(30):
            assert worse.latency(i) >= ref.latency(i) - 1e-12, (knob, i)


def test_retransmissions_geometric_inversion():
    link = JitterLossLink(loss=0.5, seed=1)
    _, u = link._draws(4)
    k = link.retransmissions(4)
    assert u <= 0.5 ** k
    assert u > 0.5 ** (k + 1)
    assert JitterLossLink(loss=0.0, seed=1).retransmissions(4) == 0


# ---------------------------------------------------------------------------
# scenario catalog
# ---------------------------------------------------------------------------

def test_scenario_catalog():
    for name in NETWORK_SCENARIOS:
        net = make_network(name, seed=2)
        assert isinstance(net, NetworkModel)
    assert type(make_network("ideal")) is NetworkModel
    with pytest.raises(ValueError, match="unknown network scenario"):
        make_network("dialup_1994")


def test_scenarios_order_qoe_clean_to_hostile():
    e = steady_emits(n=50, rate=6.0)
    q = {name: qoe_under_network(e, 0.0, TIGHT, network=make_network(name, 3))
         for name in NETWORK_SCENARIOS}
    assert q["ideal"] == pytest.approx(qoe_exact(e, 0.0, TIGHT,
                                                 response_len=e.size))
    assert q["ideal"] >= q["broadband"] >= q["lossy_wifi"]
    assert q["broadband"] >= q["satellite"]
