"""HTTP/SSE frontend (repro.server) — wire-protocol serving tests.

Layered like the server itself: SSE framing units (no socket), a
simulator-backed server for protocol behavior (healthz, metrics
round-trip, concurrency, backpressure eviction), an engine-backed
virtual-clock server for the token-identity differential, and a slowed
wall-clock server (overhead=0.05 makes tokens ~50 ms apart, wide enough
to race against) for disconnect-cancel, mid-stream drain, and the 503
barrier. The full over-the-socket wall-vs-virtual tolerance differential
runs as the CI smoke job (scripts/server_smoke.py).
"""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.core import LatencyModel, QoESpec, TPU_V5E, make_scheduler
from repro.core.request import Request
from repro.configs import get_smoke_config
from repro.obs.metrics import parse_prometheus, registry_samples_dict
from repro.serving import ServingSimulator, SimConfig
from repro.server import (SSEParser, ServerConfig, ServingServer, astream,
                          build_engine, collect, fetch, format_sse, stream)
from repro.server.app import _Conn

SPEC = QoESpec(ttft=1.0, tds=4.8)


# ---------------------------------------------------------------------------
# SSE wire format units
# ---------------------------------------------------------------------------

def test_sse_roundtrip_across_chunk_boundaries():
    frames = [format_sse("token", {"index": i, "token": 7 * i, "t": 0.1 * i})
              for i in range(20)]
    frames.append(format_sse("finish", {"qoe": 1.0}, event_id=3))
    blob = b"".join(frames)
    for size in (1, 3, 7, 64, len(blob)):
        p = SSEParser()
        evs = []
        for off in range(0, len(blob), size):
            evs.extend(p.feed(blob[off:off + size]))
        assert len(evs) == 21
        assert evs[0] == ("token", {"index": 0, "token": 0, "t": 0.0})
        assert evs[-1] == ("finish", {"qoe": 1.0})
        assert p.last_id == "3"


def test_sse_parser_spec_features():
    p = SSEParser()
    wire = (b": keep-alive comment\n"
            b"data: {\"a\": 1}\n\n"                  # no event: -> "message"
            b"event: multi\r\ndata: line1\r\ndata: line2\r\n\r\n"
            b"ignored-field: x\nevent: token\ndata: {\"i\":0}\n\n")
    evs = p.feed(wire)
    assert evs[0] == ("message", {"a": 1})
    assert evs[1] == ("multi", {"raw": "line1\nline2"})  # non-JSON payload
    assert evs[2] == ("token", {"i": 0})


# ---------------------------------------------------------------------------
# simulator-backed server: protocol behavior without jax in the loop
# ---------------------------------------------------------------------------

def _sim_backend(kv=4_000):
    cfg = get_smoke_config("llama3-8b")
    lat = LatencyModel(cfg, TPU_V5E)
    sched = make_scheduler("andes", kv, lat)
    return ServingSimulator(sched, lat, SimConfig(kv_capacity_tokens=kv))


@pytest.fixture(scope="module")
def sim_server():
    srv = ServingServer(ServerConfig(clock="virtual", warmup=False),
                        backend=_sim_backend())
    srv.start()
    yield srv
    srv.shutdown(drain=False)


def test_healthz(sim_server):
    status, body = fetch("127.0.0.1", sim_server.port, "/healthz")
    assert status == 200
    import json
    h = json.loads(body)
    assert h["ok"] and not h["draining"]


def test_unknown_route_404(sim_server):
    status, _ = fetch("127.0.0.1", sim_server.port, "/nope")
    assert status == 404


def test_stream_lifecycle_frames(sim_server):
    evs = collect("127.0.0.1", sim_server.port,
                  {"prompt_len": 8, "max_tokens": 6})
    kinds = [k for k, _ in evs]
    assert kinds[0] == "accepted" and kinds[-1] == "finish"
    assert kinds.count("token") == 6
    toks = [d for k, d in evs if k == "token"]
    assert [d["index"] for d in toks] == list(range(6))
    # §5 pacing: visible instants never violate the TDS floor
    vis = [d["visible"] for d in toks]
    assert all(b - a >= 1.0 / SPEC.tds - 1e-9
               for a, b in zip(vis, vis[1:]))
    fin = evs[-1][1]
    assert fin["n_tokens"] == 6 and 0.0 <= fin["qoe"] <= 1.0


def test_stream_network_scenario_paces_visible_times(sim_server):
    """`network` in the payload routes the SSE visible_time through the
    matching JitterLossLink — satellite 3's buffer models on the wire."""
    ideal = collect("127.0.0.1", sim_server.port,
                    {"prompt_len": 8, "max_tokens": 6, "network": "ideal"})
    sat = collect("127.0.0.1", sim_server.port,
                  {"prompt_len": 8, "max_tokens": 6, "network": "satellite"})
    v_ideal = [d["visible"] for k, d in ideal if k == "token"]
    v_sat = [d["visible"] for k, d in sat if k == "token"]
    # satellite adds >= 0.3 s propagation before the first visible token
    assert v_sat[0] >= v_ideal[0] + 0.25


def test_bad_payload_400(sim_server):
    import json as _json
    import socket
    from repro.server.client import _request_bytes, _split_head
    with socket.create_connection(("127.0.0.1", sim_server.port), 5) as s:
        s.sendall(_request_bytes("POST", "/v1/stream", "x", b"not json"))
        data = b""
        while True:
            c = s.recv(65536)
            if not c:
                break
            data += c
    status, _, _ = _split_head(data)
    assert status == 400


def test_metrics_prometheus_round_trip(sim_server):
    collect("127.0.0.1", sim_server.port, {"prompt_len": 6, "max_tokens": 4})
    status, text = fetch("127.0.0.1", sim_server.port, "/metrics")
    assert status == 200
    parsed = parse_prometheus(text)
    live = registry_samples_dict(sim_server.registry)
    assert parsed.keys() == live.keys()
    for k, v in live.items():
        assert parsed[k] == pytest.approx(v, rel=1e-6, abs=1e-9), k
    # the server-layer metrics exist and moved
    assert parsed[("requests_submitted_total", ())] >= 1
    assert parsed[("sse_events_flushed_total", ())] >= 6
    assert parsed[("connection_events_total", (("event", "open"),))] >= 1


def test_concurrent_streams(sim_server):
    import asyncio

    async def many(n):
        return await asyncio.gather(*[
            astream("127.0.0.1", sim_server.port,
                    {"prompt_len": 6, "max_tokens": 5})
            for _ in range(n)])

    results = asyncio.run(many(8))
    assert len(results) == 8
    rids = set()
    for evs in results:
        kinds = [k for k, _ in evs]
        assert kinds[0] == "accepted" and kinds[-1] == "finish"
        assert kinds.count("token") == 5
        rids.add(evs[0][1]["rid"])
    assert len(rids) == 8                      # no cross-talk between conns


def test_backpressure_evicts_slow_consumer(sim_server):
    """_offer() mechanics: a connection whose bounded queue fills is
    evicted — unread frames dropped, `evicted` + terminal sentinel queued,
    request cancelled via the pump's command queue."""
    conn = _Conn(conn_id=9999, depth=2)
    sim_server._offer(conn, [{"event": "token", "index": 0}])
    sim_server._offer(conn, [{"event": "token", "index": 1}])
    assert not conn.dead
    sim_server._offer(conn, [{"event": "token", "index": 2}])   # overflow
    assert conn.dead
    batch = conn.queue.get_nowait()
    assert batch[0]["event"] == "evicted"
    assert conn.queue.get_nowait() is None      # stream terminated
    # further offers are no-ops
    sim_server._offer(conn, [{"event": "token", "index": 3}])
    assert conn.queue.empty()


# ---------------------------------------------------------------------------
# engine-backed virtual server: token identity over the wire
# ---------------------------------------------------------------------------

def test_engine_stream_token_identity_vs_direct_run():
    """The SSE byte stream must carry exactly the token ids a direct
    virtual-clock engine run produces — the wire adds a protocol, never
    a behavior (acceptance criterion, fast half)."""
    config = ServerConfig(clock="virtual", warmup=False)
    srv = ServingServer(config)
    try:
        srv.start()
        rng = np.random.default_rng(5)
        prompts = [rng.integers(0, srv.model_cfg.vocab_size, 9).tolist()
                   for _ in range(3)]
        got = {}
        for i, toks in enumerate(prompts):
            evs = collect("127.0.0.1", srv.port,
                          {"prompt_tokens": toks, "max_tokens": 7,
                           "rid": 50 + i})
            got[50 + i] = [d["token"] for k, d in evs if k == "token"]
    finally:
        srv.shutdown(drain=False)

    _, ref_eng = build_engine(config)
    wl = [Request(rid=50 + i, arrival=0.0, prompt_len=9, output_len=7,
                  spec=SPEC, prompt_tokens=np.asarray(toks, np.int32))
          for i, toks in enumerate(prompts)]
    ref_eng.run(wl, max_iterations=2000)
    for r in wl:
        assert got[r.rid] == [int(t) for t in r.output_tokens], r.rid


# ---------------------------------------------------------------------------
# slowed wall-clock server: cancellation, drain, and the 503 barrier
# ---------------------------------------------------------------------------

def _slow_wall_server():
    """Wall engine with overhead=0.05 s/iteration: tokens ~50 ms apart,
    so client actions (disconnect, shutdown) land mid-stream reliably."""
    import jax

    from repro.models import Model
    from repro.serving import ServingEngine

    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    hw = dataclasses.replace(TPU_V5E, overhead=0.05)
    lat = LatencyModel(cfg, hw)
    sched = make_scheduler("andes", 4 * 64, lat)
    eng = ServingEngine(model, params, sched, lat, num_slots=4, max_seq=64,
                        clock="wall")
    return ServingServer(ServerConfig(clock="wall", warmup=True,
                                      drain_timeout=60.0),
                         backend=eng, model_cfg=cfg)


@pytest.fixture(scope="module")
def wall_server():
    srv = _slow_wall_server()
    srv.start()
    yield srv
    if not srv._stopped.is_set():
        srv.shutdown(drain=False)


def test_disconnect_cancels_request(wall_server):
    port = wall_server.port
    rid_seen = {}
    gen = stream("127.0.0.1", port,
                 {"prompt_len": 6, "max_tokens": 50, "rid": 700},
                 max_events=4)                 # accepted + 3 tokens, then hang up
    for k, d in gen:
        if k == "accepted":
            rid_seen[700] = d["rid"]
    assert rid_seen[700] == 700
    req = next(r for r in wall_server.backend.seen if r.rid == 700)
    deadline = time.monotonic() + 30
    while not req.cancelled and time.monotonic() < deadline:
        time.sleep(0.05)
    assert req.cancelled and req.generated < 50
    # KV slot returned to the pool so survivors can use it
    deadline = time.monotonic() + 10
    while wall_server.backend.kv.slots_in_use and time.monotonic() < deadline:
        time.sleep(0.05)
    assert wall_server.backend.kv.slots_in_use == 0
    assert wall_server.registry.value("requests_cancelled_total") >= 1


def test_graceful_drain_completes_live_streams_and_503s_new(wall_server):
    """shutdown(drain=True) mid-stream: live connections run to a clean
    `finish`, new streams bounce with 503, terminal phase is "done".
    (Last test in the file — it consumes the shared wall server.)"""
    port = wall_server.port
    results = {}
    started = threading.Barrier(4)

    def client(i):
        evs = []
        g = stream("127.0.0.1", port,
                   {"prompt_len": 6, "max_tokens": 25, "rid": 800 + i})
        for ev in g:
            evs.append(ev)
            if ev[0] == "accepted":
                started.wait(timeout=30)
        results[i] = evs

    threads = [threading.Thread(target=client, args=(i,)) for i in range(3)]
    for th in threads:
        th.start()
    started.wait(timeout=30)                   # all three streams admitted

    phase_box = {}
    shut = threading.Thread(
        target=lambda: phase_box.update(p=wall_server.shutdown(drain=True)))
    shut.start()
    deadline = time.monotonic() + 10
    while not wall_server._draining and time.monotonic() < deadline:
        time.sleep(0.005)
    assert wall_server._draining
    rejected = collect("127.0.0.1", port, {"prompt_len": 4, "max_tokens": 4})
    assert rejected and rejected[0][0] == "http_error"
    assert rejected[0][1]["status"] == 503

    shut.join(timeout=120)
    for th in threads:
        th.join(timeout=30)
    assert phase_box["p"] == "done"
    for i in range(3):
        kinds = [k for k, _ in results[i]]
        assert kinds[-1] == "finish", kinds     # drained, not killed
        assert kinds.count("token") == 25
    # drain lifecycle reached the observability layer
    assert wall_server.registry.value("drain_events_total",
                                      phase="begin") == 1
    assert wall_server.registry.value("drain_events_total",
                                      phase="done") == 1
