"""Real serving-engine integration tests: continuous batching, preemption
round-trips, Andes-on-engine, and cross-family serving."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (
    LatencyModel,
    QoESpec,
    SchedulerConfig,
    TPU_V5E,
    make_scheduler,
)
from repro.models import Model
from repro.serving import Request, ServingEngine


def mk_workload(cfg, n, rng, out_len=12, stagger=0.05):
    wl = []
    for i in range(n):
        plen = int(rng.integers(5, 20))
        wl.append(Request(
            rid=i, arrival=i * stagger, prompt_len=plen, output_len=out_len,
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen),
        ))
    return wl


def clone(wl):
    return [Request(rid=r.rid, arrival=r.arrival, prompt_len=r.prompt_len,
                    output_len=r.output_len, spec=r.spec,
                    prompt_tokens=r.prompt_tokens) for r in wl]


@pytest.fixture(scope="module")
def llama():
    cfg = get_smoke_config("llama3-8b")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


@pytest.mark.parametrize("arch", [
    "llama3-8b", "falcon-mamba-7b", "zamba2-2.7b", "qwen2-moe-a2.7b",
    "seamless-m4t-medium", "pixtral-12b",
])
@pytest.mark.slow
def test_engine_serves_all_families(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(0)
    wl = mk_workload(cfg, 5, rng, out_len=8)
    sched = make_scheduler("andes", 4 * 64, lat)
    eng = ServingEngine(m, params, sched, lat, num_slots=3, max_seq=64)
    out = eng.run(wl, max_iterations=500)
    assert all(r.generated >= r.output_len for r in out)
    assert all(len(r.emit_times) == r.generated for r in out)
    # emissions strictly ordered in time per request
    for r in out:
        assert all(b >= a for a, b in zip(r.emit_times, r.emit_times[1:]))


@pytest.mark.parametrize("mode", ["swap", "recompute"])
@pytest.mark.slow
def test_preemption_exactness(llama, mode):
    """Preempted-and-resumed requests must generate token-for-token the
    same output as an uncontended run (KV/state round-trip fidelity)."""
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(1)
    wl = mk_workload(cfg, 8, rng, out_len=15, stagger=0.01)
    sched = make_scheduler("andes", 100, lat, SchedulerConfig(delta_t=5.0))
    eng = ServingEngine(m, params, sched, lat, num_slots=2, max_seq=64,
                        capacity_tokens=100, preemption_mode=mode)
    out = eng.run(wl, max_iterations=2000)
    assert eng.preemptions > 0, "test requires contention"

    ref_eng = ServingEngine(m, params, make_scheduler("fcfs", 10_000, lat),
                            lat, num_slots=8, max_seq=64)
    ref = ref_eng.run(clone(wl), max_iterations=2000)
    for a, b in zip(out, ref):
        assert a.output_tokens == b.output_tokens, a.rid


def test_engine_kv_accounting(llama):
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(2)
    wl = mk_workload(cfg, 6, rng, out_len=10)
    sched = make_scheduler("fcfs", 10_000, lat)
    eng = ServingEngine(m, params, sched, lat, num_slots=4, max_seq=64)
    eng.run(wl, max_iterations=500)
    assert eng.kv.tokens_used == 0          # everything released
    assert len(eng.kv.free_slots) == 4
    assert not eng.kv.host_store


def test_engine_respects_capacity(llama):
    cfg, m, params = llama
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(3)
    wl = mk_workload(cfg, 10, rng, out_len=10)
    cap = 80
    sched = make_scheduler("andes", cap, lat)
    eng = ServingEngine(m, params, sched, lat, num_slots=3, max_seq=64,
                        capacity_tokens=cap)
    # track peak usage via a wrapper
    peak = 0
    orig_grow = eng.kv.grow

    def grow(req, n=1):
        nonlocal peak
        orig_grow(req, n)
        peak = max(peak, eng.kv.tokens_used)

    eng.kv.grow = grow
    out = eng.run(wl, max_iterations=2000)
    assert all(r.generated >= r.output_len for r in out)
    assert peak <= cap + 1
