import os

# Tests must see the single real CPU device (the dry-run fakes 512 devices
# in its own process only). Keep XLA quiet and single-threaded-friendly.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
