"""Property tests for the fairness policies (VTC / WSC, policies/fair.py).

The invariants pinned here are the disciplines' defining theorems,
checked end-to-end through the simulator (not on the scheduler in
isolation — preemption, re-admission and finish-time settlement all
feed the counters):

* **VTC counter-gap bound.** While every tenant is continuously
  backlogged, the spread between per-tenant service counters stays
  bounded by ONE maximum-cost request (cost = w_p·prompt + w_q·output).
  This is the VTC fairness guarantee and it is what the mid-call
  prefill-charge visibility in `VTCScheduler.schedule` buys: batching a
  tenant's admissions at a stale counter value would let the gap grow by
  several prompts per iteration.

* **WSC share convergence.** Under saturating load the *weighted*
  counters (service / weight) equalize, i.e. served-token shares
  converge to the contract weights. Measured two ways: the weighted
  counter gap obeys the same one-request bound (normalized by the
  smallest weight), and the raw service ratio lands within 20% of the
  contract weight ratio.

Saturation matters: a tenant that runs out of queued work cannot absorb
its entitlement and the theorems say nothing (that is why each tenant's
backlog is scaled by its weight, and why snapshots are only taken while
every tenant still holds several live requests).

Runs with real `hypothesis` when installed, else the deterministic
fallback in `_hypothesis_compat` (bound endpoints + seeded draws).
"""
import numpy as np
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.configs import get_config
from repro.core import (
    A100_4X,
    LatencyModel,
    QoESpec,
    SchedulerConfig,
    make_scheduler,
)
from repro.core.pricing import SLOContract
from repro.core.request import Request
from repro.serving.simulator import ServingSimulator, SimConfig

LAT = LatencyModel(get_config("opt-66b"), A100_4X)
KV = 2500


def _backlogged_workload(tenant_weights, per, seed):
    """All-at-once backlog: every request arrives in the first ~50 ms so
    each tenant is saturating for (almost) the whole run. Tenant t gets
    `per * weight_t` requests so weighted tenants don't drain early and
    stop absorbing their entitlement."""
    rng = np.random.default_rng(seed)
    reqs, rid = [], 0
    for t, w in enumerate(tenant_weights):
        contract = None if w == 1.0 else SLOContract(weight=w)
        for _ in range(int(round(per * w))):
            reqs.append(Request(
                rid=rid, arrival=0.001 * rid,
                prompt_len=int(rng.integers(60, 200)),
                output_len=int(rng.integers(30, 60)),
                spec=QoESpec(ttft=1.0, tds=4.8),
                tenant=t, contract=contract))
            rid += 1
    reqs.sort(key=lambda r: r.arrival)
    return reqs


def _run_and_snapshot(policy, workload, n_tenants, min_live=3):
    """Run the sim, snapshotting the counters at every schedule() call
    where ALL tenants still hold >= min_live live requests (the
    saturated window the fairness theorems speak about). Returns the
    scheduler and the list of counter dicts."""
    sched = make_scheduler(policy, KV, LAT, SchedulerConfig())
    sim = ServingSimulator(sched, LAT, SimConfig(kv_capacity_tokens=KV))
    snaps = []
    inner = sched.schedule

    def wrapped(now, live, fluid):
        batch = inner(now, live, fluid)
        per_t = [0] * n_tenants
        for r in live:
            per_t[r.tenant] += 1
        if all(c >= min_live for c in per_t):
            snaps.append(dict(sched.counters))
        return batch

    sched.schedule = wrapped
    sim.run(workload)
    return sched, snaps


@given(st.integers(0, 5))
@settings(max_examples=4, deadline=None)
def test_vtc_counter_gap_bounded_by_one_request(seed):
    """VTC: while all tenants are backlogged, the counter spread never
    exceeds one maximum-cost request (w_p * prompt + w_q * output)."""
    wl = _backlogged_workload([1.0, 1.0, 1.0], per=15, seed=seed)
    sched, snaps = _run_and_snapshot("vtc", wl, n_tenants=3)
    assert snaps, "no saturated window observed — workload too small"
    max_cost = max(sched.w_p * r.prompt_len + sched.w_q * r.output_len
                   for r in wl)
    worst = max(max(s.values()) - min(s.values())
                for s in snaps if len(s) == 3)
    assert worst <= max_cost, \
        f"VTC counter gap {worst:.0f} exceeds one-request bound {max_cost}"


@given(st.floats(1.25, 3.0), st.integers(0, 4))
@settings(max_examples=4, deadline=None)
def test_wsc_shares_converge_to_contract_weights(weight, seed):
    """WSC: weighted counters equalize under saturation — the weighted
    gap obeys the one-request bound (normalized by the smallest weight)
    and the raw service ratio tracks the contract weight ratio."""
    wl = _backlogged_workload([1.0, weight], per=14, seed=seed)
    sched, snaps = _run_and_snapshot("wsc", wl, n_tenants=2)
    assert snaps, "no saturated window observed — workload too small"
    # counters already store service/weight; the bound is one max-cost
    # request charged at the smallest weight (= 1.0 here, tenant 0)
    bound = max(sched.w_p * r.prompt_len + sched.w_q * r.output_len
                for r in wl)
    last = snaps[-1]
    gap = abs(last[0] - last[1])
    assert gap <= bound, \
        f"WSC weighted-counter gap {gap:.0f} exceeds bound {bound} " \
        f"(weight={weight:.2f} seed={seed})"
    # raw service ratio: counters[t] * weight_t is tokens served; shares
    # should track the weights within 20% while both are saturating
    ratio = (last[1] * weight) / max(last[0], 1e-9)
    assert abs(ratio - weight) / weight < 0.20, \
        f"WSC service ratio {ratio:.2f} far from weight {weight:.2f}"


def test_wsc_weight_monotonicity():
    """More weight -> strictly more service, and never more than the
    weight itself promises (directional sanity across the weight axis)."""
    ratios = []
    for w in (1.5, 2.0, 3.0):
        wl = _backlogged_workload([1.0, w], per=14, seed=0)
        _, snaps = _run_and_snapshot("wsc", wl, n_tenants=2)
        last = snaps[-1]
        ratios.append((last[1] * w) / max(last[0], 1e-9))
    assert ratios[0] < ratios[1] < ratios[2], \
        f"service ratios not monotone in weight: {ratios}"


def test_vtc_counter_lift_prevents_banked_credit():
    """A tenant that idles through the first half of the run must NOT
    come back with an ancient (tiny) counter and starve everyone else:
    on arrival its counter is lifted to the minimum of the active
    counters, so it competes as 'newly fair', not 'owed the past'."""
    rng = np.random.default_rng(7)
    reqs, rid = [], 0
    for j in range(20):                       # tenant 0: busy from t=0
        reqs.append(Request(
            rid=rid, arrival=0.001 * rid,
            prompt_len=int(rng.integers(60, 200)),
            output_len=int(rng.integers(30, 60)),
            spec=QoESpec(ttft=1.0, tds=4.8), tenant=0))
        rid += 1
    for j in range(6):                        # tenant 1: arrives late
        reqs.append(Request(
            rid=rid, arrival=20.0 + 0.001 * j,
            prompt_len=int(rng.integers(60, 200)),
            output_len=int(rng.integers(30, 60)),
            spec=QoESpec(ttft=1.0, tds=4.8), tenant=1))
        rid += 1
    sched = make_scheduler("vtc", KV, LAT, SchedulerConfig())
    sim = ServingSimulator(sched, LAT, SimConfig(kv_capacity_tokens=KV))
    lifted = {}
    inner = sched.on_request_arrival

    def wrapped(req):
        inner(req)
        if req.tenant == 1 and 1 not in lifted:
            lifted[1] = sched.counters.get(1, 0.0)
            lifted[0] = sched.counters.get(0, 0.0)
    sched.on_request_arrival = wrapped
    sim.run(reqs)
    # at tenant 1's first arrival, tenant 0 had banked real service; the
    # lift must have set tenant 1's counter to that floor, not zero
    assert lifted[0] > 0.0
    assert lifted[1] == lifted[0], \
        f"late tenant counter {lifted[1]:.0f} not lifted to floor {lifted[0]:.0f}"
