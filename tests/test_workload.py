"""Workload generation (§6.1): arrivals, lengths, QoE traces."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.workload import (
    gamma_arrivals,
    make_workload,
    poisson_arrivals,
    reading_qoe_trace,
    sample_lengths,
    voice_qoe_trace,
)


def test_poisson_rate():
    rng = np.random.default_rng(0)
    a = poisson_arrivals(3.3, 20_000, rng)
    rate = len(a) / a[-1]
    assert abs(rate - 3.3) / 3.3 < 0.05


def test_gamma_same_mean_higher_cv():
    rng = np.random.default_rng(0)
    g = gamma_arrivals(3.3, 50_000, rng, cv=3.0)
    gaps = np.diff(np.concatenate([[0], g]))
    assert abs(gaps.mean() - 1 / 3.3) / (1 / 3.3) < 0.05
    cv = gaps.std() / gaps.mean()
    assert cv > 2.0     # bursty


def test_lengths_reasonable():
    rng = np.random.default_rng(0)
    p, o = sample_lengths(20_000, rng, "sharegpt")
    assert 100 < np.median(p) < 250          # Fig. 9 ShareGPT inputs
    assert 150 < np.median(o) < 300
    assert p.max() <= 1024 and o.max() <= 1024
    p2, _ = sample_lengths(20_000, rng, "multiround")
    assert np.median(p2) > 2.0 * np.median(p)   # ~3x longer inputs


def test_reading_trace_mean():
    rng = np.random.default_rng(0)
    specs = reading_qoe_trace(10_000, rng)
    tds = np.array([s.tds for s in specs])
    assert 4.2 < tds.mean() < 5.2            # paper: ~4.8 tokens/s
    assert all(s.ttft == 1.0 for s in specs)


def test_voice_trace_slower():
    rng = np.random.default_rng(0)
    r = np.mean([s.tds for s in reading_qoe_trace(5000, rng)])
    v = np.mean([s.tds for s in voice_qoe_trace(5000, rng)])
    assert v < r                              # speaking < reading
    assert 3.0 < v < 4.0                      # paper: ~3.3 tokens/s


@given(st.integers(1, 200), st.floats(0.5, 10.0), st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_workload_wellformed(n, rate, seed):
    wl = make_workload(n, rate, seed=seed)
    assert len(wl) == n
    arr = [r.arrival for r in wl]
    assert arr == sorted(arr)
    for r in wl:
        assert r.prompt_len >= 4 and r.output_len >= 4
        assert r.spec.tds > 0 and r.spec.ttft > 0
