"""Workload generation (§6.1): arrivals, lengths, QoE traces."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.workload import (
    gamma_arrivals,
    make_workload,
    poisson_arrivals,
    reading_qoe_trace,
    sample_lengths,
    voice_qoe_trace,
)


def test_poisson_rate():
    rng = np.random.default_rng(0)
    a = poisson_arrivals(3.3, 20_000, rng)
    rate = len(a) / a[-1]
    assert abs(rate - 3.3) / 3.3 < 0.05


def test_gamma_same_mean_higher_cv():
    rng = np.random.default_rng(0)
    g = gamma_arrivals(3.3, 50_000, rng, cv=3.0)
    gaps = np.diff(np.concatenate([[0], g]))
    assert abs(gaps.mean() - 1 / 3.3) / (1 / 3.3) < 0.05
    cv = gaps.std() / gaps.mean()
    assert cv > 2.0     # bursty


def test_lengths_reasonable():
    rng = np.random.default_rng(0)
    p, o = sample_lengths(20_000, rng, "sharegpt")
    assert 100 < np.median(p) < 250          # Fig. 9 ShareGPT inputs
    assert 150 < np.median(o) < 300
    assert p.max() <= 1024 and o.max() <= 1024
    p2, _ = sample_lengths(20_000, rng, "multiround")
    assert np.median(p2) > 2.0 * np.median(p)   # ~3x longer inputs


def test_reading_trace_mean():
    rng = np.random.default_rng(0)
    specs = reading_qoe_trace(10_000, rng)
    tds = np.array([s.tds for s in specs])
    assert 4.2 < tds.mean() < 5.2            # paper: ~4.8 tokens/s
    assert all(s.ttft == 1.0 for s in specs)


def test_voice_trace_slower():
    rng = np.random.default_rng(0)
    r = np.mean([s.tds for s in reading_qoe_trace(5000, rng)])
    v = np.mean([s.tds for s in voice_qoe_trace(5000, rng)])
    assert v < r                              # speaking < reading
    assert 3.0 < v < 4.0                      # paper: ~3.3 tokens/s


@given(st.integers(1, 200), st.floats(0.5, 10.0), st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_workload_wellformed(n, rate, seed):
    wl = make_workload(n, rate, seed=seed)
    assert len(wl) == n
    arr = [r.arrival for r in wl]
    assert arr == sorted(arr)
    for r in wl:
        assert r.prompt_len >= 4 and r.output_len >= 4
        assert r.spec.tds > 0 and r.spec.ttft > 0


# ---------------------------------------------------------------------------
# Adversarial traces (policy arena, PR 7)
# ---------------------------------------------------------------------------

def _trace_key(reqs):
    return [(r.rid, r.arrival, r.prompt_len, r.output_len, r.tenant,
             None if r.contract is None else
             (r.contract.weight, r.contract.qoe_floor))
            for r in reqs]


@pytest.mark.parametrize("name", ["burst", "heavy_tail", "greedy_tenant"])
def test_adversarial_trace_seed_stability(name):
    """Same (name, n, rate, seed) -> byte-identical trace; different seed
    -> different trace. The arena scoreboard artifact is only
    reproducible (BENCH validation without rewrite) if this holds."""
    from repro.workload import ADVERSARIAL_TRACES, make_adversarial_workload

    assert name in ADVERSARIAL_TRACES
    a = make_adversarial_workload(name, 120, 5.0, seed=9)
    b = make_adversarial_workload(name, 120, 5.0, seed=9)
    c = make_adversarial_workload(name, 120, 5.0, seed=10)
    assert _trace_key(a) == _trace_key(b)
    assert _trace_key(a) != _trace_key(c)
    # well-formed: sorted arrivals, contiguous rids (retagged), tenants set
    assert [r.rid for r in a] == list(range(len(a)))
    arr = [r.arrival for r in a]
    assert arr == sorted(arr)
    assert len({r.tenant for r in a}) >= 2


def test_adversarial_traces_are_adversarial():
    """Each generator must actually produce its pathology: synchronized
    arrival spikes, heavy-tailed prompts, one tenant dominating."""
    from repro.workload import (
        greedy_tenant_workload,
        heavy_tail_workload,
        synchronized_burst_workload,
    )

    burst = synchronized_burst_workload(400, 5.0, seed=0, burst_every=30.0)
    gaps = np.diff([r.arrival for r in burst])
    # a synchronized burst packs many arrivals into near-zero gaps
    assert np.mean(gaps < 0.05) > 0.25

    tail = heavy_tail_workload(400, 5.0, seed=0)
    prompts = np.array([r.prompt_len for r in tail])
    assert prompts.max() / np.median(prompts) > 5.0   # elephants exist

    greedy = greedy_tenant_workload(400, 5.0, seed=0, greedy_share=0.7)
    counts = {}
    for r in greedy:
        counts[r.tenant] = counts.get(r.tenant, 0) + 1
    top = max(counts.values())
    assert top / len(greedy) > 0.5                     # one tenant dominates
