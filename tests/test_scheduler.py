"""Scheduler unit tests: Algorithm 1 greedy, Algorithm 2 DP, invariants."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.configs import get_config
from repro.core import (
    A100_4X,
    LatencyModel,
    QoESpec,
    SchedulerConfig,
    make_scheduler,
)
from repro.core.qoe import FluidQoE
from repro.core.request import Request, ReqState
from repro.core.scheduler import AndesDPScheduler, AndesScheduler

CFG = get_config("opt-66b")
LAT = LatencyModel(CFG, A100_4X)


def mk_requests(n, rng, prompt_hi=500):
    reqs = []
    fluid = FluidQoE()
    for i in range(n):
        r = Request(
            rid=i, arrival=float(i) * 0.1,
            prompt_len=int(rng.integers(10, prompt_hi)),
            output_len=int(rng.integers(10, 500)),
            spec=QoESpec(ttft=1.0, tds=float(rng.uniform(3, 6))),
        )
        r.fluid_idx = fluid.add(r.arrival, r.spec)
        reqs.append(r)
    return reqs, fluid


# ---------------------------------------------------------------------------
# greedy packing (Algorithm 1)
# ---------------------------------------------------------------------------

def test_greedy_respects_memory_and_batch():
    sched = make_scheduler("andes", 1000, LAT)
    gains = np.array([0.9, 0.8, 0.7, 0.6, 0.5])
    weights = np.array([400, 400, 400, 100, 100])
    sel, _ = sched._solve(gains, weights, b=3)
    assert weights[sel].sum() <= 1000
    assert sel.sum() <= 3


def test_greedy_prefers_high_priority():
    sched = make_scheduler("andes", 500, LAT)
    gains = np.array([1.0, 1.0])
    weights = np.array([500, 100])   # same gain, cheaper wins
    sel, _ = sched._solve(gains, weights, b=1)
    assert sel[1] and not sel[0]


@given(st.integers(1, 40), st.integers(2, 12), st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_greedy_never_violates_constraints(n, b, seed):
    rng = np.random.default_rng(seed)
    gains = rng.uniform(-0.5, 1.0, n)
    weights = rng.integers(1, 800, n)
    m = int(rng.integers(100, 3000))
    sched = make_scheduler("andes", m, LAT)
    sel, value = sched._solve(gains, weights, b)
    assert weights[sel].sum() <= m
    assert sel.sum() <= b
    assert value == pytest.approx(gains[sel].sum())


# ---------------------------------------------------------------------------
# DP (Algorithm 2) vs greedy: DP optimal on small instances
# ---------------------------------------------------------------------------

@given(st.integers(1, 10), st.integers(1, 6), st.integers(0, 5000))
@settings(max_examples=40, deadline=None)
def test_dp_at_least_as_good_as_greedy(n, b, seed):
    """Algorithm 2 solves the *exact-B* knapsack (paper Eq. 4); the
    scheduler enumerates candidate B values, so compare best-over-B'<=B
    against the greedy's <=B packing."""
    rng = np.random.default_rng(seed)
    gains = rng.uniform(0.0, 1.0, n)
    weights = rng.integers(1, 8, n) * 64    # granularity-aligned weights
    m = 16 * 64
    greedy = make_scheduler("andes", m, LAT)
    dp = make_scheduler("andes_dp", m, LAT, granularity=64)
    _, vg = greedy._solve(gains, weights, b)
    vd = max(dp._solve(gains, weights, bb)[1] for bb in range(1, b + 1))
    assert vd >= vg - 1e-9


def test_dp_exact_small_case():
    """Hand-checkable exact-k knapsack instance."""
    dp = AndesDPScheduler(4 * 64, LAT, granularity=64)
    gains = np.array([0.6, 0.5, 0.45, 0.2])
    weights = np.array([3 * 64, 2 * 64, 2 * 64, 1 * 64])
    sel, val = dp._solve(gains, weights, b=2)
    # best 2 items under 4 units: items 1+2 (weights 2+2, gain 0.95)
    assert val == pytest.approx(0.95)
    assert list(np.nonzero(sel)[0]) == [1, 2]


# ---------------------------------------------------------------------------
# scheduling behaviour
# ---------------------------------------------------------------------------

def test_fcfs_admission_order():
    rng = np.random.default_rng(0)
    reqs, fluid = mk_requests(10, rng, prompt_hi=100)
    sched = make_scheduler("fcfs", 350, LAT)
    out = sched.schedule(1.0, reqs, fluid)
    # admitted must be a prefix in arrival order (until memory bound)
    rids = [r.rid for r in out]
    assert rids == sorted(rids)
    assert sum(r.kv_tokens() for r in out) <= 350


def test_andes_admits_all_when_underloaded():
    rng = np.random.default_rng(1)
    reqs, fluid = mk_requests(5, rng, prompt_hi=50)
    sched = make_scheduler("andes", 10_000, LAT)
    out = sched.schedule(1.0, reqs, fluid)
    assert len(out) == 5


def test_andes_respects_memory_under_pressure():
    rng = np.random.default_rng(2)
    reqs, fluid = mk_requests(50, rng)
    m = 2000
    sched = make_scheduler("andes", m, LAT)
    out = sched.schedule(5.0, reqs, fluid)
    assert sum(r.kv_tokens() for r in out) <= m


def test_andes_prioritizes_starving_over_buffered():
    """The paper's core behaviour: a request that already has plenty of
    buffered tokens is preempted in favour of a queued starving one."""
    spec = QoESpec(ttft=1.0, tds=5.0)
    fluid = FluidQoE()
    buffered = Request(rid=0, arrival=0.0, prompt_len=400, output_len=300, spec=spec)
    buffered.state = ReqState.RUNNING
    buffered.generated = 150
    buffered.fluid_idx = fluid.add(0.0, spec)
    for t in 0.2 + np.arange(150) / 60.0:   # served at 60 tok/s: big buffer
        fluid.emit(np.array([buffered.fluid_idx]), float(t), 1)

    starving = Request(rid=1, arrival=0.1, prompt_len=400, output_len=300, spec=spec)
    starving.fluid_idx = fluid.add(0.1, spec)

    m = 600   # only one fits
    sched = make_scheduler("andes", m, LAT)
    sched.total_requests = 2
    out = sched.schedule(3.0, [buffered, starving], fluid)
    assert any(r.rid == 1 for r in out), "starving request must be scheduled"


def test_preemption_cap_limits_churn():
    rng = np.random.default_rng(3)
    reqs, fluid = mk_requests(30, rng)
    for r in reqs[:20]:
        r.state = ReqState.RUNNING
    sched = make_scheduler("andes", 4000, LAT,
                           SchedulerConfig(preemption_cap=0.0))
    sched.total_requests = 30
    out = sched.schedule(5.0, reqs, fluid)
    running_kept = sum(1 for r in reqs[:20] if r in out)
    # cap 0: no running request may be preempted (unless memory forces it)
    kept_tokens = sum(r.kv_tokens() for r in out)
    assert kept_tokens <= 4000
    preempted = 20 - running_kept
    # allowed only if memory could not hold them
    assert preempted == 0 or kept_tokens > 4000 - 600
