"""Simulator-vs-engine cross-validation.

Same scheduler class, same latency model, same workload: the discrete-event
simulator and the real engine (virtual clock) must agree on the scheduling-
level outcomes. This is what lets the paper-scale simulator results stand
in for runs this CPU container cannot execute (DESIGN.md §7).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import LatencyModel, QoESpec, SchedulerConfig, TPU_V5E, make_scheduler
from repro.cluster import ClusterConfig, ClusterSimulator, engine_backend
from repro.models import Model
from repro.serving import Request, ServingEngine
from repro.serving.simulator import ServingSimulator, SimConfig


def mk_wl(cfg, rng, n=8, out_len=12):
    wl = []
    for i in range(n):
        plen = int(rng.integers(8, 24))
        wl.append(Request(
            rid=i, arrival=i * 0.2, prompt_len=plen, output_len=out_len,
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen),
        ))
    return wl


def clone(wl):
    return [r.clone() for r in wl]


@pytest.mark.parametrize("sched_name", ["fcfs", "andes"])
def test_sim_matches_engine_timings(sched_name):
    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(0)
    wl = mk_wl(cfg, rng)

    cap = 8 * 64
    eng = ServingEngine(model, params,
                        make_scheduler(sched_name, cap, lat, SchedulerConfig()),
                        lat, num_slots=8, max_seq=64, capacity_tokens=cap)
    out_e = eng.run(clone(wl), max_iterations=2000)

    sim = ServingSimulator(
        make_scheduler(sched_name, cap, lat, SchedulerConfig()),
        lat, SimConfig(kv_capacity_tokens=cap),
    )
    out_s = sim.run(clone(wl)).requests

    for re_, rs in zip(out_e, out_s):
        assert re_.generated == rs.generated
        # per-request TTFT agreement within 20% or 50 ms
        te, ts = re_.final_ttft(), rs.final_ttft()
        assert abs(te - ts) < max(0.05, 0.2 * ts), (re_.rid, te, ts)
        qe, qs = re_.final_qoe(), rs.final_qoe()
        assert abs(qe - qs) < 0.1, (re_.rid, qe, qs)


@pytest.mark.parametrize("seed", [0, 1])
def test_fleet_sim_vs_engine_per_replica(seed):
    """Sim-vs-engine agreement holds *per replica inside a fleet*: feed
    the same trace through the same deterministic router to a
    simulator-backed and an engine-backed 2-replica cluster; every
    request must land on the same replica, and each replica's scheduling
    trace must agree within the single-engine tolerances above."""
    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(seed)
    wl = mk_wl(cfg, rng, n=12)

    cap = 8 * 64
    common = dict(n_replicas=2, router="round_robin", scheduler="andes",
                  kv_capacity_tokens=cap)
    res_sim = ClusterSimulator(lat, ClusterConfig(**common)).run(clone(wl))
    res_eng = ClusterSimulator(lat, ClusterConfig(
        **common,
        backend_factory=engine_backend(model, params, num_slots=8,
                                       max_seq=64, capacity_tokens=cap),
    )).run(clone(wl))

    assert res_sim.replica_results.keys() == res_eng.replica_results.keys()
    for rid in res_sim.replica_results:
        per_sim = res_sim.replica_results[rid].requests
        per_eng = res_eng.replica_results[rid].requests
        # identical placement (router decisions are backend-independent)
        assert [r.rid for r in per_sim] == [r.rid for r in per_eng], rid
        assert len(per_sim) > 0, rid
        for re_, rs in zip(per_eng, per_sim):
            assert re_.generated == rs.generated, (rid, re_.rid)
            te, ts = re_.final_ttft(), rs.final_ttft()
            assert abs(te - ts) < max(0.05, 0.2 * ts), (rid, re_.rid, te, ts)
            qe, qs = re_.final_qoe(), rs.final_qoe()
            assert abs(qe - qs) < 0.1, (rid, re_.rid, qe, qs)
