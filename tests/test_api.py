"""Unified serving API (repro.api): differential + contract suite.

The ServingClient must be a pure *surface*: driving any backend through
`client.submit_request` + `drain()` (or lazy stream iteration) is
bit-identical — emit timestamps, preemption counts, final QoE — to
driving that backend directly with its own submit/step loop. Verified
here for all four backend kinds: discrete-event simulator, real-model
engine, speculative engine, and a 1-replica cluster.

The contract layer (core.pricing.SLOContract) must *reduce*: attaching
uniform default contracts to every request reproduces the PR 1 uniform
admission threshold decisions exactly, and uncontracted traffic prices
at weight 1.0 through the whole stack (scheduler knapsack gains are
multiplied by exactly 1.0 — an IEEE identity). Non-uniform weights must
then bite: under surge, the high-weight tenant is shed less.
"""
import copy

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.configs import get_config
from repro.core import (
    A100_4X,
    LatencyModel,
    QoESpec,
    SchedulerConfig,
    SLOContract,
    make_scheduler,
    request_weight,
    slo_attained,
    weighted_attainment,
)
from repro.core import pricing
from repro.core.qoe import pace_delivery
from repro.core.request import Request
from repro.cluster import (
    AdmissionConfig,
    ClusterConfig,
    ClusterSimulator,
    marginal_qoe_gain,
)
from repro.cluster.router import RouterConfig
from repro.api import ServingClient, SubmitOptions
from repro.serving.simulator import ServingSimulator, SimConfig
from repro.workload import make_workload

CFG = get_config("opt-66b")
LAT = LatencyModel(CFG, A100_4X)
M = 65_000


def make_sim(scheduler="andes", kv=M):
    sched = make_scheduler(scheduler, kv, LAT, SchedulerConfig())
    return ServingSimulator(sched, LAT, SimConfig(kv_capacity_tokens=kv))


def assert_streams_match(direct_reqs, handles):
    """Bit-for-bit: emit timestamps, preemptions, final QoE per rid."""
    d = {r.rid: r for r in direct_reqs}
    assert len(d) == len(handles)
    for h in handles:
        r = d[h.rid]
        assert r.emit_times == h.request.emit_times
        assert r.preemptions == h.request.preemptions
        assert r.final_qoe() == h.qoe()


# ---------------------------------------------------------------------------
# Differential: client ≡ direct driving, per backend kind
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ["andes", "fcfs"])
def test_client_over_simulator_bit_identical(scheduler):
    wl = make_workload(100, 4.0, seed=11, arrival="gamma", cv=3.0)
    direct = make_sim(scheduler).run(copy.deepcopy(wl))
    client = ServingClient(make_sim(scheduler))
    res = client.serve(copy.deepcopy(wl))     # the one-liner replay path
    assert_streams_match(direct.requests, client.handles())
    # the client's result() is the backend's own snapshot
    assert res.total_tokens == direct.total_tokens
    assert res.makespan == direct.makespan


def test_client_over_one_replica_cluster_bit_identical():
    """Client → 1-replica cluster ≡ direct cluster ≡ bare simulator."""
    wl = make_workload(100, 4.0, seed=13, arrival="gamma", cv=3.0)
    ccfg = ClusterConfig(n_replicas=1, kv_capacity_tokens=M)
    direct = ClusterSimulator(LAT, ccfg).run(copy.deepcopy(wl))
    bare = make_sim().run(copy.deepcopy(wl))
    client = ServingClient(
        ClusterSimulator(LAT, ClusterConfig(n_replicas=1,
                                            kv_capacity_tokens=M)))
    handles = [client.submit_request(r) for r in copy.deepcopy(wl)]
    client.drain()
    assert_streams_match(direct.admitted, handles)
    assert_streams_match(bare.requests, handles)


def test_client_lazy_stream_iteration_matches_drain():
    """Pulling streams one token at a time (stepping on demand, in rid
    order) yields the same timeline as draining wholesale."""
    wl = make_workload(60, 4.0, seed=17, arrival="gamma", cv=3.0)
    direct = make_sim().run(copy.deepcopy(wl))
    client = ServingClient(make_sim())
    handles = [client.submit_request(r) for r in copy.deepcopy(wl)]
    events = {h.rid: list(h) for h in handles}     # lazy, interleaved
    assert_streams_match(direct.requests, handles)
    d = {r.rid: r for r in direct.requests}
    for rid, evs in events.items():
        assert [e.emit_time for e in evs] == d[rid].emit_times
        # §5 pacing: the event visible_times are pace_delivery of emits
        want = pace_delivery(np.array(d[rid].emit_times), d[rid].spec.tds)
        np.testing.assert_array_equal([e.visible_time for e in evs], want)


@pytest.mark.parametrize("spec_k", [0, 2])
def test_client_over_engine_bit_identical(spec_k):
    """Real-model engine (and its speculative variant) behind the client
    ≡ the same engine driven via run()."""
    import jax

    from repro.configs import get_smoke_config
    from repro.core import SpeculativeLatencyModel, TPU_V5E
    from repro.models import Model
    from repro.serving import ServingEngine

    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(5)
    wl = []
    for i in range(8):
        plen = int(rng.integers(8, 24))
        wl.append(Request(
            rid=i, arrival=i * 0.02, prompt_len=plen,
            output_len=int(rng.integers(8, 16)),
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen),
        ))

    def build():
        if spec_k:
            lat = SpeculativeLatencyModel(cfg, TPU_V5E, cfg, k=spec_k)
            extra = dict(draft_model=model, draft_params=params,
                         spec_k=spec_k)
        else:
            lat = LatencyModel(cfg, TPU_V5E)
            extra = {}
        return ServingEngine(
            model, params, make_scheduler("andes", 160, lat), lat,
            num_slots=3, max_seq=64, capacity_tokens=160, **extra)

    direct_wl = [r.clone() for r in wl]
    build().run(direct_wl)

    client = ServingClient(build())
    handles = [client.submit_request(r.clone()) for r in wl]
    client.drain()
    assert_streams_match(direct_wl, handles)
    # token ids stream through the handle: read() yields every emitted
    # token exactly once, even when the backend was drained wholesale
    d = {r.rid: r for r in direct_wl}
    for h in handles:
        assert h.tokens() == d[h.rid].output_tokens
        assert [e.token for e in h.read()] == d[h.rid].output_tokens


# ---------------------------------------------------------------------------
# Lifecycle callbacks
# ---------------------------------------------------------------------------

def test_lifecycle_callbacks_fire_consistently():
    # tight KV forces preemptions so on_preempt is exercised
    wl = make_workload(80, 8.0, seed=3, arrival="gamma", cv=3.0)
    client = ServingClient(make_sim(kv=12_000))
    counts = {}

    def track(kind):
        def cb(h, t, k=1):
            counts.setdefault(h.rid, {}).setdefault(kind, 0)
            counts[h.rid][kind] += k if kind == "emit" else 1
        return cb

    handles = [client.submit_request(
        r, on_first_token=track("first"), on_emit=track("emit"),
        on_preempt=track("preempt"), on_finish=track("finish"))
        for r in wl]
    client.drain()
    assert any(h.request.preemptions > 0 for h in handles)
    for h in handles:
        c = counts[h.rid]
        assert c["emit"] == h.request.generated
        assert c["first"] == 1
        assert c["finish"] == 1
        assert c.get("preempt", 0) == h.request.preemptions


def test_shed_stream_ends_empty_with_zero_qoe():
    cfg = ClusterConfig(
        n_replicas=1, router="qoe", kv_capacity_tokens=4_000,
        admission=AdmissionConfig(policy="shed"),
    )
    wl = make_workload(150, 40.0, seed=2, arrival="gamma", cv=3.0)
    client = ServingClient(ClusterSimulator(LAT, cfg))
    handles = [client.submit_request(r) for r in wl]
    client.drain()
    shed = [h for h in handles if h.shed]
    assert shed, "surge should shed something"
    for h in shed:
        assert list(h) == []
        assert h.done and not h.finished
        assert h.qoe() == 0.0


# ---------------------------------------------------------------------------
# SLO contracts: reduction + pricing
# ---------------------------------------------------------------------------

def run_admission(wl, contract=None, policy="shed"):
    cfg = ClusterConfig(
        n_replicas=2, router="qoe", kv_capacity_tokens=10_000,
        admission=AdmissionConfig(policy=policy),
    )
    wl = [r.clone() for r in wl]
    for r in wl:
        r.contract = contract
    return ClusterSimulator(LAT, cfg).run(wl)


@given(st.integers(0, 10_000), st.integers(0, 1))
@settings(max_examples=6, deadline=None)
def test_uniform_contracts_reduce_to_uniform_threshold(seed, policy_i):
    """Property: attaching the *same default* SLOContract to every request
    changes nothing — admission decisions, emit timelines, and QoE are
    bit-identical to the uncontracted PR 1 uniform min_gain threshold."""
    policy = ("shed", "defer")[policy_i]
    wl = make_workload(60, 25.0, seed=seed, arrival="gamma", cv=3.0)
    base = run_admission(wl, contract=None, policy=policy)
    uni = run_admission(wl, contract=SLOContract(), policy=policy)
    assert [r.rid for r in base.shed] == [r.rid for r in uni.shed]
    assert base.n_defer_events == uni.n_defer_events
    b = {r.rid: r for r in base.admitted}
    for r in uni.admitted:
        assert r.emit_times == b[r.rid].emit_times
    assert base.avg_qoe() == uni.avg_qoe()


def test_contract_weight_shifts_shedding_to_low_weight_tenant():
    """Under surge, weight-w pricing sheds the low-weight tail first."""
    gold = SLOContract(weight=4.0)
    scrap = SLOContract(weight=0.25)
    wl = make_workload(160, 30.0, seed=4, arrival="gamma", cv=3.0)
    for i, r in enumerate(wl):
        r.tenant = i % 2
        r.contract = gold if r.tenant == 0 else scrap
    cfg = ClusterConfig(
        n_replicas=2, router="qoe", kv_capacity_tokens=6_000,
        admission=AdmissionConfig(policy="shed"),
    )
    res = ClusterSimulator(LAT, cfg).run(wl)
    shed_by_tenant = {0: 0, 1: 0}
    for r in res.shed:
        shed_by_tenant[r.tenant] += 1
    assert sum(shed_by_tenant.values()) > 0, "surge should shed"
    assert shed_by_tenant[0] < shed_by_tenant[1]


def test_rid_collisions_are_impossible_per_session():
    """submit() skips rids a trace replay took; submit_request refuses a
    duplicate outright (per-rid reporting and admission's defer counts
    would silently conflate two live requests)."""
    client = ServingClient(make_sim())
    h0 = client.submit(50)                       # auto rid 0
    assert h0.rid == 0
    r5 = Request(rid=5, arrival=0.0, prompt_len=10, output_len=4,
                 spec=QoESpec(ttft=1.0, tds=4.8))
    client.submit_request(r5)
    with pytest.raises(ValueError):
        client.submit_request(r5.clone())        # rid 5 again
    assert client.submit(50).rid == 1            # fills the gap...
    for _ in range(4):
        client.submit(50)                        # ...then skips past 5
    assert sorted(h.rid for h in client.handles()) == [0, 1, 2, 3, 4, 5, 6]


def test_degradation_priced_at_victim_contract_weights():
    """placement pricing values each live victim's QoE loss at ITS
    contract weight — the same fleet objective the knapsack and the
    attainment signal use (uniform weights reduce to the PR 1 sum)."""
    from repro.cluster import Replica
    sched = make_scheduler("andes", 3_000, LAT, SchedulerConfig())
    sim = ServingSimulator(sched, LAT, SimConfig(kv_capacity_tokens=3_000))
    rep = Replica(0, sim, LAT)
    for i in range(10):
        rep.submit(Request(rid=i, arrival=0.0, prompt_len=300,
                           output_len=300, spec=QoESpec(ttft=1.0, tds=4.8)))
    for _ in range(30):
        rep.step()
    now = rep.clock
    newcomer = Request(rid=99, arrival=now, prompt_len=300, output_len=300,
                       spec=QoESpec(ttft=1.0, tds=4.8))
    rcfg = RouterConfig()
    kw = dict(horizon=rcfg.horizon, min_remaining_est=rcfg.min_remaining_est)
    q1, d1 = pricing.placement_components(rep, newcomer, now, **kw)
    assert d1 > 0, "saturated replica must predict degradation"
    for r in rep.live:
        r.contract = SLOContract(weight=2.0)
    q2, d2 = pricing.placement_components(rep, newcomer, now, **kw)
    assert q2 == q1
    assert d2 == pytest.approx(2.0 * d1)


def test_request_weight_and_attainment_semantics():
    r = Request(rid=0, arrival=0.0, prompt_len=10, output_len=4,
                spec=QoESpec(ttft=1.0, tds=4.0))
    assert request_weight(r) == 1.0
    r.priority = 2
    assert request_weight(r) == 3.0
    r.contract = SLOContract(weight=0.5)
    assert request_weight(r) == 1.5
    r.priority = 0
    # attainment: perfect delivery meets a lenient contract, not a strict
    # TTFT target
    r.emit_times = [0.5, 0.75, 1.0, 1.25]
    r.generated = 4
    assert slo_attained(r, default_floor=0.9)
    r.contract = SLOContract(ttft_target=0.1)
    assert not slo_attained(r, default_floor=0.9)
    r.contract = SLOContract(qoe_floor=0.2, ttft_target=1.0, tds_target=1.0)
    assert slo_attained(r, default_floor=0.99)
    # weighted attainment: the failing request drags proportionally to w
    r2 = Request(rid=1, arrival=0.0, prompt_len=10, output_len=4,
                 spec=QoESpec(ttft=1.0, tds=4.0))
    r2.contract = SLOContract(weight=3.0, ttft_target=0.0)  # unattainable
    r2.emit_times = [0.5]
    r2.generated = 1
    r.contract = SLOContract(weight=1.0)
    assert weighted_attainment([r, r2], 0.9) == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# One pricing surface (no duplicated marginal-gain math)
# ---------------------------------------------------------------------------

def test_router_gain_is_the_pricer_gain():
    """marginal_qoe_gain is a delegation to core.pricing.placement_gain
    with the request's contract/priority weight — not a second copy."""
    from repro.core.scheduler import Scheduler
    sched = make_scheduler("andes", M, LAT, SchedulerConfig())
    sim = ServingSimulator(sched, LAT, SimConfig(kv_capacity_tokens=M))
    from repro.cluster import Replica
    rep = Replica(0, sim, LAT)
    rcfg = RouterConfig()
    req = Request(rid=0, arrival=0.0, prompt_len=100, output_len=100,
                  spec=QoESpec(ttft=1.0, tds=4.8))
    got = marginal_qoe_gain(rep, req, 0.0, rcfg)
    want = pricing.placement_gain(
        rep, req, 0.0, horizon=rcfg.horizon,
        min_remaining_est=rcfg.min_remaining_est, weight=1.0)
    assert got == want
    # weight scales exactly the newcomer term
    req.contract = SLOContract(weight=2.0)
    q_new, deg = pricing.placement_components(
        rep, req, 0.0, horizon=rcfg.horizon,
        min_remaining_est=rcfg.min_remaining_est)
    assert marginal_qoe_gain(rep, req, 0.0, rcfg) == 2.0 * q_new - deg
    # every scheduler owns a pricer bound to itself (live lat/M views)
    assert isinstance(sched, Scheduler) and sched.pricer.sched is sched
    assert sched.pricer.lat is sched.lat
    assert sched.pricer.kv_capacity == sched.M
