"""Sharding rules + dry-run machinery on a small faked-device mesh.

conftest pins this test process to 1 CPU device, so these tests spawn a
subprocess with --xla_force_host_platform_device_count to build real meshes
(same pattern as launch/dryrun.py).
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SMALL_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs import get_smoke_config
from repro.distributed.sharding import param_specs, batch_specs, cache_specs, make_shardings
from repro.launch.mesh import make_debug_mesh
from repro.models import Model

results = {}
mesh = make_debug_mesh(2, 4)
for arch in ["llama3-8b", "qwen2-moe-a2.7b", "falcon-mamba-7b", "zamba2-2.7b"]:
    cfg = get_smoke_config(arch)
    model = Model(cfg, param_dtype=jnp.float32)
    params = model.abstract_params()
    specs = param_specs(mesh, params)
    # every leaf got a spec; rank matches
    ok = True
    for (path, leaf), (_, spec) in zip(
        jax.tree_util.tree_flatten_with_path(params)[0],
        jax.tree_util.tree_flatten_with_path(specs, is_leaf=lambda x: isinstance(x, P))[0],
    ):
        if len(spec) > len(leaf.shape):
            ok = False
    # lower+compile a real train step on the small mesh
    shard = make_shardings(mesh, specs)
    b = {"tokens": jax.ShapeDtypeStruct((4, 16), jnp.int32),
         "labels": jax.ShapeDtypeStruct((4, 16), jnp.int32)}
    bs = make_shardings(mesh, batch_specs(mesh, b, cfg))
    f = jax.jit(model.loss, in_shardings=(shard, bs))
    compiled = f.lower(params, b).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax<0.5 wraps the dict in a list
        cost = cost[0] if cost else {}
    results[arch] = {"ok": ok, "flops": float(cost.get("flops", 0))}

    # decode path compiles too
    cache = model.init_cache(4, 32, dtype=jnp.float32, abstract=True)
    cs = make_shardings(mesh, cache_specs(mesh, cache, cfg))
    ts = make_shardings(mesh, batch_specs(mesh, {"t": jax.ShapeDtypeStruct((4,), jnp.int32)}, cfg))["t"]
    g = jax.jit(model.decode_step, in_shardings=(shard, ts, cs), out_shardings=(None, cs))
    g.lower(params, jax.ShapeDtypeStruct((4,), jnp.int32), cache).compile()
    results[arch]["decode_ok"] = True
print(json.dumps(results))
"""


@pytest.mark.slow
def test_sharding_rules_compile_on_small_mesh():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-c", SMALL_MESH_SCRIPT],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    assert len(results) == 4
    for arch, r in results.items():
        assert r["ok"] and r["decode_ok"], (arch, r)
        assert r["flops"] > 0


def test_hlo_stats_parser():
    from repro.launch.hlo_stats import collective_stats
    hlo = """
HloModule test

%cond (x: s32[]) -> pred[] {
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%x, %c), direction=LT
}

%body (x: s32[]) -> s32[] {
  %ag = f32[128,64]{1,0} all-gather(%p), channel_id=1, replica_groups=[2,4]<=[8], dimensions={1}
  ROOT %n = s32[] add(%x, %one)
}

ENTRY %main () -> f32[] {
  %w = (s32[]) while(%t), condition=%cond, body=%body
  %ar = f32[256]{0} all-reduce(%z), channel_id=2, replica_groups=[2,4]<=[8]
  ROOT %r = f32[] constant(0)
}
"""
    stats = collective_stats(hlo)
    # all-gather inside 12-trip while: 128*64*4 * (3/4) * 12
    assert stats.count_by_op["all-gather"] == 12
    assert stats.bytes_by_op["all-gather"] == pytest.approx(
        128 * 64 * 4 * (3 / 4) * 12)
    # all-reduce in entry: 256*4 * 2 * 3/4
    assert stats.bytes_by_op["all-reduce"] == pytest.approx(256 * 4 * 2 * 0.75)


def test_dryrun_results_exist_and_wellformed():
    """The 40-combo baselines (both meshes) produced by launch/dryrun.py."""
    d = os.path.join(REPO, "experiments", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("dry-run artifacts not generated yet")
    files = [f for f in os.listdir(d) if f.endswith(".json")]
    pod = [f for f in files if f.endswith("_pod.json")]
    multi = [f for f in files if f.endswith("_multipod.json")]
    assert len(pod) >= 40, f"expected 40 single-pod baselines, got {len(pod)}"
    assert len(multi) >= 40, f"expected 40 multi-pod runs, got {len(multi)}"
    for f in files[:10]:
        with open(os.path.join(d, f)) as fh:
            r = json.load(fh)
        assert r["hlo_flops_per_device"] > 0
        assert r["roofline"]["dominant"] in ("compute_s", "memory_s",
                                             "collective_s")
