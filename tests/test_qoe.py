"""QoE metric (paper §3.1, Eq. 1): unit + property tests."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core.qoe import (
    FluidQoE,
    QoESpec,
    actual_area,
    expected_area,
    pace_delivery,
    qoe_exact,
)

SPEC = QoESpec(ttft=1.0, tds=5.0)


# ---------------------------------------------------------------------------
# Token buffer pacing
# ---------------------------------------------------------------------------

def test_pacing_slows_burst():
    # 10 tokens all at t=0 -> visible every 1/tds
    d = pace_delivery(np.zeros(10), tds=5.0)
    np.testing.assert_allclose(d, np.arange(10) / 5.0)


def test_pacing_passthrough_when_slow():
    e = np.arange(10) * 1.0   # 1 tok/s < tds
    d = pace_delivery(e, tds=5.0)
    np.testing.assert_allclose(d, e)


@given(st.lists(st.floats(0, 100), min_size=1, max_size=50),
       st.floats(0.5, 20))
@settings(max_examples=100, deadline=None)
def test_pacing_properties(emits, tds):
    e = np.sort(np.array(emits))
    d = pace_delivery(e, tds)
    assert np.all(d >= e - 1e-12)                    # never before emission
    assert np.all(np.diff(d) >= 1.0 / tds - 1e-9)    # never faster than tds
    assert d[0] == e[0]                              # first token immediate


# ---------------------------------------------------------------------------
# Eq. 1 QoE
# ---------------------------------------------------------------------------

def test_perfect_delivery_gives_one():
    # tokens arrive exactly on the expected TDT
    l = 20
    e = SPEC.ttft + np.arange(l) / SPEC.tds
    assert qoe_exact(e, 0.0, SPEC, response_len=l) == pytest.approx(1.0)


def test_early_delivery_still_one():
    l = 20
    e = 0.1 + np.arange(l) / 50.0    # much faster than needed
    assert qoe_exact(e, 0.0, SPEC, response_len=l) == pytest.approx(1.0)


def test_late_ttft_hurts():
    l = 20
    on_time = SPEC.ttft + np.arange(l) / SPEC.tds
    late = 10.0 + np.arange(l) / SPEC.tds
    q_on = qoe_exact(on_time, 0.0, SPEC, response_len=l)
    q_late = qoe_exact(late, 0.0, SPEC, response_len=l)
    assert q_late < q_on


def test_slower_tds_hurts():
    l = 30
    good = SPEC.ttft + np.arange(l) / SPEC.tds
    slow = SPEC.ttft + np.arange(l) / (SPEC.tds / 2)
    assert qoe_exact(slow, 0.0, SPEC, response_len=l) < \
        qoe_exact(good, 0.0, SPEC, response_len=l)


def test_earlier_tokens_better_same_ttft_ttlt():
    """Paper principle 3 / Fig. 2: front-loaded delivery beats back-loaded
    even with identical TTFT and TTLT."""
    ttft, ttlt, l = 1.0, 21.0, 40
    front = np.concatenate([np.linspace(ttft, 8, 30), np.linspace(8.5, ttlt, 10)])
    back = np.concatenate([np.linspace(ttft, 14, 10), np.linspace(14.5, ttlt, 30)])
    q_front = qoe_exact(front, 0.0, SPEC, response_len=l)
    q_back = qoe_exact(back, 0.0, SPEC, response_len=l)
    assert q_front > q_back


@given(
    st.lists(st.floats(0.01, 60), min_size=2, max_size=60),
    st.floats(0.2, 3.0),
    st.floats(1.0, 10.0),
)
@settings(max_examples=100, deadline=None)
def test_qoe_bounded(emits, ttft, tds):
    e = np.sort(np.array(emits))
    q = qoe_exact(e, 0.0, QoESpec(ttft, tds), response_len=len(e))
    assert 0.0 <= q <= 1.0


@given(st.floats(0.1, 30), st.floats(1, 10), st.floats(0.2, 3))
@settings(max_examples=60, deadline=None)
def test_expected_area_monotone(t, tds, ttft):
    spec = QoESpec(ttft, tds)
    a1 = expected_area(t, spec, cap=50)
    a2 = expected_area(t + 1.0, spec, cap=50)
    assert a2 >= a1


# ---------------------------------------------------------------------------
# Fluid model vs exact metric
# ---------------------------------------------------------------------------

def test_fluid_matches_exact_on_steady_stream():
    spec = QoESpec(ttft=1.0, tds=5.0)
    fl = FluidQoE()
    i = fl.add(0.0, spec)
    e = 0.5 + np.arange(100) / 5.0     # exactly on pace, early start
    for t in e:
        fl.emit(np.array([i]), float(t), 1)
    q_fluid = fl.qoe_now(float(e[-1]))[i]
    q_exact = qoe_exact(e, 0.0, spec)
    assert abs(q_fluid - q_exact) < 0.08


def test_fluid_predict_wait_decays_for_starved():
    spec = QoESpec(ttft=1.0, tds=5.0)
    fl = FluidQoE()
    i = fl.add(0.0, spec)
    fl.emit(np.array([i]), 1.0, 1)     # one token, then silence
    q_soon = fl.predict_qoe(2.0, 5.0, 0.0, exp_len=np.array([100.0]))[i]
    q_late = fl.predict_qoe(2.0, 50.0, 0.0, exp_len=np.array([100.0]))[i]
    assert q_late < q_soon


def test_fluid_predict_serve_beats_wait():
    spec = QoESpec(ttft=1.0, tds=5.0)
    fl = FluidQoE()
    i = fl.add(0.0, spec)
    q_wait = fl.predict_qoe(0.5, 20.0, 0.0, exp_len=np.array([100.0]))[i]
    q_serve = fl.predict_qoe(0.5, 20.0, 8.0, exp_len=np.array([100.0]))[i]
    assert q_serve > q_wait


# ---------------------------------------------------------------------------
# Burst emission (speculative decoding: one verify step emits k tokens)
# ---------------------------------------------------------------------------

@given(st.integers(1, 6), st.floats(0.1, 10.0), st.floats(1.0, 10.0),
       st.floats(0.5, 20.0))
@settings(max_examples=60, deadline=None)
def test_burst_emit_equals_unit_emits(k, t, dt, tds):
    """emit(idx, t, k) must leave the fluid state exactly where k unit
    emits at the same instant would — including the first-token-immediate
    release — and accrue the same actual area ever after."""
    spec = QoESpec(ttft=1.0, tds=tds)
    burst, units = FluidQoE(), FluidQoE()
    i = burst.add(0.0, spec)
    units.add(0.0, spec)
    burst.emit(i, t, k)
    for _ in range(k):
        units.emit(i, t, 1)
    for f in FluidQoE.FIELDS:
        np.testing.assert_allclose(getattr(burst, f), getattr(units, f),
                                   rtol=1e-12, err_msg=f)
    burst.advance(t + dt)
    units.advance(t + dt)
    np.testing.assert_allclose(burst.s_act, units.s_act, rtol=1e-12)
    np.testing.assert_allclose(burst.n_vis, units.n_vis, rtol=1e-12)


@given(st.integers(2, 8), st.floats(0.0, 10.0), st.floats(0.5, 20.0))
@settings(max_examples=60, deadline=None)
def test_pacing_smooths_burst_to_spec_tds(k, t, tds):
    """A k-token burst at time t is released by the client buffer at
    exactly the spec'd TDS: first token immediately, then 1/tds apart."""
    d = pace_delivery(np.full(k, t), tds)
    np.testing.assert_allclose(d, t + np.arange(k) / tds, rtol=1e-12)


def test_burst_qoe_equals_smooth_qoe_when_on_pace():
    """Eq. 1 is evaluated on the *paced* delivery curve, so a server that
    front-runs its pace in k-token bursts scores the same QoE as one
    emitting perfectly smoothly — the property that makes burst delivery
    (speculative decoding) QoE-neutral when throughput is sufficient."""
    spec = QoESpec(ttft=1.0, tds=5.0)
    l, k = 24, 4
    smooth = spec.ttft + np.arange(l) / spec.tds
    # same schedule, but tokens arrive k at a time at the burst head
    burst = np.repeat(smooth[::k], k)[:l]
    q_smooth = qoe_exact(smooth, 0.0, spec, response_len=l)
    q_burst = qoe_exact(burst, 0.0, spec, response_len=l)
    assert q_burst == pytest.approx(q_smooth)
    assert q_burst == pytest.approx(1.0)


def test_fluid_burst_vs_exact_on_bursty_stream():
    """Fluid burst accounting tracks the exact metric on a k-at-a-time
    emission pattern (the speculative engine's native output shape)."""
    spec = QoESpec(ttft=1.0, tds=5.0)
    k, n_bursts = 4, 25
    times = 0.5 + np.arange(n_bursts) * (k / 5.0)
    fl = FluidQoE()
    i = fl.add(0.0, spec)
    emits = []
    for t in times:
        fl.emit(np.array([i]), float(t), k)
        emits.extend([t] * k)
    q_fluid = fl.qoe_now(float(times[-1]))[i]
    q_exact = qoe_exact(np.array(emits), 0.0, spec)
    assert abs(q_fluid - q_exact) < 0.08


def test_fluid_sufficiently_served_high_q_wait():
    """A request with a big client buffer should have high Q_wait (it is
    safe to preempt) vs a starving one (urgent)."""
    spec = QoESpec(ttft=1.0, tds=5.0)
    fl = FluidQoE()
    buffered = fl.add(0.0, spec)
    starving = fl.add(0.0, spec)
    # buffered got 80 tokens quickly; starving got 5 then nothing
    for k, t in enumerate(0.2 + np.arange(80) / 40.0):
        fl.emit(np.array([buffered]), float(t), 1)
    for t in 0.2 + np.arange(5) / 40.0:
        fl.emit(np.array([starving]), float(t), 1)
    exp_len = np.array([100.0, 100.0])
    q = fl.predict_qoe(3.0, 15.0, 0.0, exp_len=exp_len)
    assert q[buffered] > q[starving]
