"""Real-model replicas in the cluster, validated engine-as-oracle.

The cluster layer only ever talks to a replica through the
`SteppableBackend` protocol, so a stepped `ServingEngine` (real JAX
model, virtual clock, tiny granite-class config) plugs in where the
discrete-event simulator normally sits. These tests pin down the three
levels of agreement that make the fleet results trustworthy:

  1. a 1-replica engine-backed cluster reproduces the bare engine
     bit-for-bit (the cluster layer adds decisions *around* the engine,
     never inside it — same invariant PR 1 proved for the simulator);
  2. mixed fleets (simulator replicas next to engine replicas) serve a
     shared trace to completion through one router;
  3. the engine-backed cluster agrees with the simulator-backed cluster
     per replica (see test_sim_vs_engine.py for the fleet extension).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import LatencyModel, QoESpec, SchedulerConfig, TPU_V5E
from repro.cluster import (
    ClusterConfig,
    ClusterSimulator,
    engine_backend,
    mixed_backends,
    simulator_backend,
)
from repro.models import Model
from repro.serving import Request, ServingEngine, ServingSimulator
from repro.core.scheduler import make_scheduler

NUM_SLOTS = 8
MAX_SEQ = 64
CAP = NUM_SLOTS * MAX_SEQ


@pytest.fixture(scope="module")
def granite():
    cfg = get_smoke_config("granite-3-2b")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def mk_wl(cfg, rng, n=10, out_len=10, stagger=0.2):
    wl = []
    for i in range(n):
        plen = int(rng.integers(8, 24))
        wl.append(Request(
            rid=i, arrival=i * stagger, prompt_len=plen, output_len=out_len,
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen),
        ))
    return wl


def clone(wl):
    return [r.clone() for r in wl]


def engine_cluster_cfg(m, params, *, n_replicas=1, router="round_robin",
                       scheduler="andes"):
    return ClusterConfig(
        n_replicas=n_replicas,
        router=router,
        scheduler=scheduler,
        kv_capacity_tokens=CAP,
        backend_factory=engine_backend(
            m, params, num_slots=NUM_SLOTS, max_seq=MAX_SEQ,
            capacity_tokens=CAP,
        ),
    )


# ---------------------------------------------------------------------------
# 1-replica invariance: routed engine ≡ bare engine, bit-for-bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", [
    pytest.param("fcfs", marks=pytest.mark.slow),
    "andes",
])
def test_one_replica_engine_cluster_matches_bare_engine(granite, scheduler):
    cfg, m, params = granite
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(0)
    wl = mk_wl(cfg, rng)

    bare = ServingEngine(
        m, params, make_scheduler(scheduler, CAP, lat, SchedulerConfig()),
        lat, num_slots=NUM_SLOTS, max_seq=MAX_SEQ, capacity_tokens=CAP,
    )
    out_bare = bare.run(clone(wl), max_iterations=2000)

    res = ClusterSimulator(
        lat, engine_cluster_cfg(m, params, scheduler=scheduler)
    ).run(clone(wl))

    assert len(res.shed) == 0
    assert len(res.admitted) == len(wl)
    for a, b in zip(sorted(res.admitted, key=lambda r: r.rid), out_bare):
        assert a.rid == b.rid
        assert a.output_tokens == b.output_tokens, a.rid
        assert a.emit_times == b.emit_times, a.rid       # exact floats
        assert a.preemptions == b.preemptions, a.rid
        assert a.final_qoe() == b.final_qoe(), a.rid


def test_engine_backend_aligns_scheduler_capacity(granite):
    """With no explicit capacity_tokens the engine clamps to what the
    slot cache physically holds — and the replica's scheduler M must be
    re-pointed at the same number, or the router/admission layers price
    KV the engine does not have."""
    cfg, m, params = granite
    lat = LatencyModel(cfg, TPU_V5E)
    cs = ClusterSimulator(lat, ClusterConfig(
        n_replicas=1, router="round_robin", kv_capacity_tokens=65_000,
        backend_factory=engine_backend(m, params, num_slots=4, max_seq=64),
    ))
    rep = cs.replicas[0]
    assert rep.backend.kv.capacity_tokens == 4 * 64
    assert rep.kv_capacity == 4 * 64          # sched.M matches the engine


def test_engine_replica_backend_is_real_engine(granite):
    cfg, m, params = granite
    lat = LatencyModel(cfg, TPU_V5E)
    cs = ClusterSimulator(lat, engine_cluster_cfg(m, params))
    assert isinstance(cs.replicas[0].backend, ServingEngine)
    # the replica views the engine through the protocol only
    assert cs.replicas[0].kv_capacity == CAP
    assert cs.replicas[0].clock == 0.0


# ---------------------------------------------------------------------------
# mixed fleets: simulator replicas next to real-model replicas
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("router", ["round_robin", "qoe"])
def test_mixed_sim_engine_fleet_serves_to_completion(granite, router):
    cfg, m, params = granite
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(1)
    wl = mk_wl(cfg, rng, n=14, out_len=8, stagger=0.1)

    cluster_cfg = ClusterConfig(
        n_replicas=2,
        router=router,
        kv_capacity_tokens=CAP,
        backend_factory=mixed_backends([
            engine_backend(m, params, num_slots=NUM_SLOTS,
                           max_seq=MAX_SEQ, capacity_tokens=CAP),
            simulator_backend,
        ]),
    )
    cs = ClusterSimulator(lat, cluster_cfg)
    assert isinstance(cs.replicas[0].backend, ServingEngine)
    assert isinstance(cs.replicas[1].backend, ServingSimulator)

    res = cs.run(clone(wl))
    assert len(res.shed) == 0
    assert all(r.generated >= r.output_len for r in res.admitted)
    served = {rid: len(r.requests) for rid, r in res.replica_results.items()}
    if router == "round_robin":
        # strict alternation puts traffic on both; the QoE router may
        # legitimately herd a light load onto the replica it prices best
        assert all(n > 0 for n in served.values()), served
    assert sum(served.values()) == len(wl)
    q = res.qoes()
    assert q.size == len(wl) and (q >= 0).all() and (q <= 1).all()
    # the engine replica emits real tokens; the simulator replica does not
    eng_reqs = res.replica_results[0].requests
    assert all(len(r.output_tokens) == r.generated for r in eng_reqs)


def test_engine_fleet_load_views(granite):
    """Router load views (committed, kv_demand) work through the engine
    backend mid-flight, not just at the end."""
    cfg, m, params = granite
    lat = LatencyModel(cfg, TPU_V5E)
    rng = np.random.default_rng(2)
    wl = mk_wl(cfg, rng, n=4, out_len=6, stagger=0.0)

    cs = ClusterSimulator(lat, engine_cluster_cfg(m, params))
    rep = cs.replicas[0]
    for r in clone(wl):
        rep.submit(r)
    assert len(rep.committed()) == 4
    assert rep.kv_demand() > 0
    assert rep.has_work
    rep.advance_to(0.5)
    assert rep.clock >= 0.5 or not rep.has_work
    while rep.step():
        pass
    assert not rep.has_work
    res = rep.result()
    assert res.total_tokens == sum(r.generated for r in res.requests)
