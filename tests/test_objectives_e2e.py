"""Alternative objectives (Appendix A) drive the scheduler end-to-end."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import A100_4X, LatencyModel, SchedulerConfig, make_scheduler
from repro.core.objectives import avg_qoe, max_min_qoe, perfect_count
from repro.serving.simulator import ServingSimulator, SimConfig
from repro.workload import make_workload


def test_objective_functions_shapes():
    qs = np.array([1.0, 0.5, 0.2])
    qw = np.array([0.9, 0.1, 0.2])
    qn = np.array([1.0, 0.6, 0.3])
    assert avg_qoe(qs, qw, qn).shape == (3,)
    np.testing.assert_allclose(avg_qoe(qs, qw, qn), qs - qw)
    mm = max_min_qoe(qs, qw, qn)
    # the floor request (lowest q_wait given Q_min anchor) earns the most
    assert np.argmax(mm) == 1
    # only the currently-perfect request earns the primary perfect-count
    # gain (+ the epsilon avg-QoE tiebreak, see objectives.EPS_TIEBREAK)
    pc = perfect_count(qs, qw, qn)
    assert pc[0] == pytest.approx(1.0, abs=0.02)
    assert pc[1] == pytest.approx(0.0, abs=0.02)
    assert pc[2] == pytest.approx(0.0, abs=0.02)
    assert pc[0] > pc[1] + 0.9


@pytest.mark.parametrize("objective", ["max_min_qoe", "perfect_count"])
def test_objectives_run_e2e(objective):
    cfg = get_config("opt-66b")
    lat = LatencyModel(cfg, A100_4X)
    wl = make_workload(200, 4.5, seed=3)
    sched = make_scheduler("andes", 30_000, lat,
                           SchedulerConfig(objective=objective))
    res = ServingSimulator(sched, lat, SimConfig(kv_capacity_tokens=30_000)).run(wl)
    assert all(r.generated >= r.output_len for r in res.requests)
    assert res.avg_qoe() > 0.3


@pytest.mark.slow
def test_max_min_lifts_floor_vs_fcfs():
    cfg = get_config("opt-66b")
    lat = LatencyModel(cfg, A100_4X)

    def floor(name, objective="avg_qoe"):
        wl = make_workload(300, 5.0, seed=4)
        sched = make_scheduler(name, 25_000, lat,
                               SchedulerConfig(objective=objective))
        res = ServingSimulator(sched, lat,
                               SimConfig(kv_capacity_tokens=25_000)).run(wl)
        return float(np.percentile(res.qoes(), 5))

    assert floor("andes", "max_min_qoe") > floor("fcfs") + 0.05
