"""Benchmark entrypoint: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per row plus each module's
validation line against the paper's claims. ``--full`` uses the full trace
lengths (default is the quick profile suitable for CI).

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig10,...]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks import (
    appendixA_objectives,
    cluster_qoe,
    engine_hotpath,
    fig03_motivation,
    fig10_qoe_sharegpt,
    fig11_qoe_multiround,
    fig12_throughput,
    fig13_preemption,
    fig15_robustness,
    fig16_18_sensitivity,
    fig21_norm_latency,
    kernels_micro,
    policy_arena,
    roofline,
    table4_breakdown,
)

MODULES = {
    "fig03": fig03_motivation,
    "fig10": fig10_qoe_sharegpt,
    "fig11": fig11_qoe_multiround,
    "fig12": fig12_throughput,
    "fig13": fig13_preemption,
    "table4": table4_breakdown,
    "fig15": fig15_robustness,
    "fig16_18": fig16_18_sensitivity,
    "fig21": fig21_norm_latency,
    "appendixA": appendixA_objectives,
    "cluster": cluster_qoe,
    "hotpath": engine_hotpath,
    "arena": policy_arena,
    "kernels": kernels_micro,
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full trace lengths (slower, tighter numbers)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module keys")
    args = ap.parse_args()
    quick = not args.full
    keys = args.only.split(",") if args.only else list(MODULES)

    print("name,us_per_call,derived")
    summaries = []
    for key in keys:
        mod = MODULES[key]
        t0 = time.perf_counter()
        try:
            rows = mod.run(quick=quick)
        except Exception as e:  # noqa: BLE001 — surface failures in CSV
            print(f"{key},0,ERROR:{e!r}")
            summaries.append((key, f"ERROR {e!r}"))
            continue
        us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
        for r in rows:
            derived = {k: v for k, v in r.items() if k != "name"}
            print(f"{r['name']},{r.get('us_per_call', round(us, 1))},"
                  f"\"{json.dumps(derived)}\"")
        if hasattr(mod, "validate"):
            summaries.append((key, mod.validate(rows)))

    print("\n== validation against paper claims ==", file=sys.stderr)
    for key, line in summaries:
        print(f"  {key:10s} {line}", file=sys.stderr)


if __name__ == "__main__":
    main()
