"""Kernel microbenchmarks: Pallas (interpret) correctness + XLA-path wall
time per call for the three serving hot-spots. On this CPU container the
meaningful number is the XLA ref path; the Pallas kernels are validated for
correctness and their BlockSpec tiling is exercised."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time_it(fn, *args, iters=5):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6   # us


def run(quick: bool = False):
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 6)

    # decode attention (the Andes-driven hot loop)
    b, s, h, kv, hd = (8, 512, 8, 2, 64) if quick else (16, 2048, 16, 4, 64)
    q = jax.random.normal(ks[0], (b, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    lengths = jnp.full((b,), s, jnp.int32)
    f = jax.jit(lambda *a: ops.decode_attention(*a, impl="ref"))
    us = _time_it(f, q, k, v, lengths)
    # pallas interpret correctness
    outp = ops.decode_attention(q[:2], k[:2], v[:2], lengths[:2], impl="pallas")
    outr = ref.decode_attention_ref(q[:2], k[:2], v[:2], lengths[:2])
    err = float(jnp.max(jnp.abs(outp - outr)))
    rows.append({"name": "kernel/decode_attention", "us_per_call": round(us, 1),
                 "pallas_max_err": f"{err:.1e}"})

    # flash attention prefill
    b2, s2 = (2, 512) if quick else (4, 2048)
    q2 = jax.random.normal(ks[3], (b2, s2, 8, 64))
    k2 = jax.random.normal(ks[4], (b2, s2, 2, 64))
    v2 = jax.random.normal(ks[5], (b2, s2, 2, 64))
    f2 = jax.jit(lambda *a: ops.attention(*a, causal=True, impl="ref"))
    us2 = _time_it(f2, q2, k2, v2)
    outp = ops.attention(q2[:1, :256], k2[:1, :256], v2[:1, :256],
                         causal=True, impl="pallas")
    outr = ref.attention_ref(q2[:1, :256], k2[:1, :256], v2[:1, :256],
                             causal=True)
    err2 = float(jnp.max(jnp.abs(outp - outr)))
    rows.append({"name": "kernel/flash_attention", "us_per_call": round(us2, 1),
                 "pallas_max_err": f"{err2:.1e}"})

    # selective scan
    b3, s3, d3, n3 = (2, 512, 256, 16) if quick else (4, 2048, 512, 16)
    x = jax.random.normal(ks[0], (b3, s3, d3))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b3, s3, d3)) - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (d3, n3)) * 0.5)
    B = jax.random.normal(ks[3], (b3, s3, n3))
    C = jax.random.normal(ks[4], (b3, s3, n3))
    D = jnp.ones((d3,)) * 0.3
    f3 = jax.jit(lambda *a: ops.selective_scan(*a, impl="chunked"))
    us3 = _time_it(f3, x, dt, A, B, C, D)
    outp = ops.selective_scan(x[:1, :128, :64], dt[:1, :128, :64], A[:64],
                              B[:1, :128], C[:1, :128], D[:64], impl="pallas")
    outr = ref.selective_scan_ref(x[:1, :128, :64], dt[:1, :128, :64], A[:64],
                                  B[:1, :128], C[:1, :128], D[:64])
    err3 = float(jnp.max(jnp.abs(outp - outr)))
    rows.append({"name": "kernel/selective_scan", "us_per_call": round(us3, 1),
                 "pallas_max_err": f"{err3:.1e}"})
    return rows


def validate(rows) -> str:
    ok = all(float(r["pallas_max_err"]) < 1e-3 for r in rows)
    return f"all Pallas kernels match oracles (interpret mode): {ok}"


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print(validate(rows))
