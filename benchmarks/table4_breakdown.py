"""Table 4 + Fig. 14 — percentile breakdown (QoE / TTFT / TDS) at the
paper's operating point (OPT-66B, ShareGPT, rate 3.3), and the QoE-vs-
length scatter (Andes starves only a small tail of long requests)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_point

RATE = 4.2   # = ~1.17x our FCFS capacity, the paper's 3.3/2.8 overload depth
PCTS = (10, 50, 90)


def run(quick: bool = False):
    rows = []
    per_sched = {}
    for sched in ("fcfs", "andes"):
        res = run_point(sched, RATE, n=800 if quick else 2000, quick=False)
        per_sched[sched] = res
        q, t, s = res.qoes(), res.ttfts(), res.tds()
        row = {"name": f"table4/{sched}"}
        for p in PCTS:
            row[f"qoe_p{p}"] = round(float(np.percentile(q, p)), 2)
            row[f"ttft_p{p}"] = round(float(np.percentile(t, p)), 2)
            row[f"tds_p{p}"] = round(float(np.percentile(s, p)), 2)
        rows.append(row)

    # Fig. 14: fraction of long vs short requests with QoE < 0.5
    for sched, res in per_sched.items():
        tot = np.array([r.prompt_len + r.output_len for r in res.requests])
        q = res.qoes()
        long_mask = tot > np.percentile(tot, 75)
        rows.append({
            "name": f"fig14/{sched}",
            "starved_long_pct": round(100 * float(np.mean(q[long_mask] < 0.5)), 1),
            "starved_short_pct": round(100 * float(np.mean(q[~long_mask] < 0.5)), 1),
        })
    return rows


def validate(rows) -> str:
    t4 = {r["name"]: r for r in rows}
    fcfs, andes = t4["table4/fcfs"], t4["table4/andes"]
    f14f, f14a = t4["fig14/fcfs"], t4["fig14/andes"]
    return (
        f"median TTFT {fcfs['ttft_p50']}s -> {andes['ttft_p50']}s "
        f"(paper: 56.7 -> 0.47); QoE p10 {fcfs['qoe_p10']} -> {andes['qoe_p10']} "
        f"(paper: 0.05 -> 0.77); FCFS starves short requests "
        f"({f14f['starved_short_pct']}%), Andes only a long tail "
        f"({f14a['starved_long_pct']}% long vs {f14a['starved_short_pct']}% short)"
    )


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print(validate(rows))
