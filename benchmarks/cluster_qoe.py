"""Cluster serving — router policy × fleet size × burst cv (+ admission).

Extends the paper's single-engine evaluation (§6) to the fleet layer
(repro.cluster): N replicas fed by one gamma-burst arrival trace, with the
Andes scheduler inside every replica. The sweep compares fleet routers on
a *heterogeneous* fleet (alternating 4xA100 / 4xA40 — both hardware points
the paper itself evaluates, Fig. 15a), which is where routing policy has
real leverage: DiSCo-style capability-aware dispatch beats queue feedback
that cannot tell a fast replica from a slow one, and both beat blind
round-robin. A second section shows admission control degrading gracefully
under deep surge (§6.4 fleet-wide): shedding/deferring negative-gain
requests lifts the QoE of everyone actually served.

Every sweep drives its backend — fleet, bare engine, or speculative
engine — through the unified `repro.api.ServingClient` (the `_serve`
helper), the same submit/stream surface as the examples; `make bench-api`
runs the default sweep as a one-liner.

Run via `python -m benchmarks.run --only cluster` (CSV rows, like every
figure module) or `python -m benchmarks.cluster_qoe [--out cluster.json]`
for a standalone JSON dump. `--engine` cross-checks real-model replicas
against the simulator fleet; `--speculative` reports the speculative
engine's lossless token-identity gate and decode-step reduction vs the
baseline engine (`make bench-spec`).
"""
from __future__ import annotations

import numpy as np

from repro.api import ServingClient
from repro.configs import get_config
from repro.core import A40_4X, A100_4X, LatencyModel
from repro.cluster import AdmissionConfig, ClusterConfig, ClusterSimulator
from repro.workload import make_workload

MODEL = "opt-66b"
KV_PER_REPLICA = 40_000
ROUTERS = ("round_robin", "jsq", "qoe")
# per-fleet-size aggregate rates: ~near the heterogeneous fleet's capacity
# (1xA100+1xA40 sustains ~4.2 req/s of the reading trace)
FLEET_POINTS = {2: 4.5, 4: 9.0}


def _lat_models():
    cfg = get_config(MODEL)
    return [LatencyModel(cfg, A100_4X), LatencyModel(cfg, A40_4X)]


def _serve(backend, wl):
    """Drive any backend (fleet or bare engine) through the unified
    client (repro.api) — bit-identical to driving the backend directly
    (tests/test_api.py)."""
    return ServingClient(backend).serve(wl)


def _run_point(router: str, n_replicas: int, rate: float, cv: float,
               seed: int, n: int):
    cfg = ClusterConfig(
        n_replicas=n_replicas,
        router=router,
        kv_capacity_tokens=KV_PER_REPLICA,
    )
    wl = make_workload(n, rate, seed=seed, arrival="gamma", cv=cv)
    return _serve(ClusterSimulator(_lat_models(), cfg), wl)


def _router_sweep(quick: bool):
    rows = []
    seeds = (1, 2, 3) if quick else (1, 2, 3, 4, 5)
    cvs = (3.0,) if quick else (1.5, 3.0, 6.0)
    n = 400 if quick else 600
    for n_replicas, rate in FLEET_POINTS.items():
        for cv in cvs:
            qoes = {}
            for router in ROUTERS:
                per_seed = [
                    _run_point(router, n_replicas, rate, cv, s, n).avg_qoe()
                    for s in seeds
                ]
                qoes[router] = float(np.mean(per_seed))
                rows.append({
                    "name": (f"cluster/replicas={n_replicas}/cv={cv}"
                             f"/{router}"),
                    "avg_qoe": round(qoes[router], 4),
                    "qoe_std": round(float(np.std(per_seed)), 4),
                })
            rows.append({
                "name": f"cluster/replicas={n_replicas}/cv={cv}/derived",
                "qoe_minus_jsq": round(qoes["qoe"] - qoes["jsq"], 4),
                "qoe_minus_rr": round(qoes["qoe"] - qoes["round_robin"], 4),
            })
    return rows


def _admission_sweep(quick: bool):
    """Deep surge on an undersized homogeneous fleet: admitting everything
    is fleet-QoE-negative; shed/defer protect the served."""
    rows = []
    lat = LatencyModel(get_config(MODEL), A100_4X)
    n = 300 if quick else 500
    served_qoe = {}
    for policy in ("none", "shed", "defer"):
        cfg = ClusterConfig(
            n_replicas=2, router="qoe", kv_capacity_tokens=12_000,
            admission=AdmissionConfig(policy=policy),
        )
        wl = make_workload(n, 20.0, seed=2, arrival="gamma", cv=3.0)
        res = _serve(ClusterSimulator(lat, cfg), wl)
        served_qoe[policy] = res.avg_qoe(include_shed=False)
        rows.append({
            "name": f"cluster/admission/{policy}",
            "avg_qoe_served": round(res.avg_qoe(include_shed=False), 4),
            "avg_qoe_incl_shed": round(res.avg_qoe(), 4),
            "shed": len(res.shed),
            "defer_events": res.n_defer_events,
        })
    rows.append({
        "name": "cluster/admission/derived",
        "shed_served_uplift": round(served_qoe["shed"] - served_qoe["none"], 4),
        "defer_served_uplift": round(
            served_qoe["defer"] - served_qoe["none"], 4),
    })
    return rows


def _engine_sweep(quick: bool):
    """Engine-backed mode: the same cluster layer, but every replica runs
    the real JAX model (granite-class smoke config, virtual clock) through
    the steppable ServingEngine. Reported next to a simulator-backed fleet
    with identical scheduler/router/capacity on the identical trace — the
    fleet-level engine-as-oracle check, as a benchmark row instead of a
    test assertion."""
    import jax

    from repro.configs import get_smoke_config
    from repro.core import TPU_V5E
    from repro.core.qoe import QoESpec
    from repro.core.request import Request
    from repro.cluster import engine_backend
    from repro.models import Model
    from repro.workload.arrivals import gamma_arrivals

    cfg = get_smoke_config("granite-3-2b")
    model_obj = Model(cfg)
    params = model_obj.init(jax.random.PRNGKey(0))
    lat = LatencyModel(cfg, TPU_V5E)
    # tight per-replica KV budget so the sweep exercises queueing and
    # preemption, not just an idle fleet
    num_slots, max_seq = 4, 64
    cap = 150

    n = 24 if quick else 60
    rng = np.random.default_rng(3)
    arrivals = gamma_arrivals(12.0, n, rng, cv=3.0)
    wl_proto = [
        Request(rid=i, arrival=float(arrivals[i]),
                prompt_len=int(rng.integers(8, 32)),
                output_len=int(rng.integers(8, 24)),
                spec=QoESpec(ttft=1.0, tds=4.8))
        for i in range(n)
    ]

    def clone():
        return [r.clone() for r in wl_proto]

    rows = []
    for router in ("round_robin", "qoe"):
        common = dict(n_replicas=2, router=router,
                      kv_capacity_tokens=cap)
        res_sim = _serve(ClusterSimulator(lat, ClusterConfig(**common)),
                         clone())
        res_eng = _serve(ClusterSimulator(lat, ClusterConfig(
            **common,
            backend_factory=engine_backend(
                model_obj, params, num_slots=num_slots, max_seq=max_seq,
                capacity_tokens=cap),
        )), clone())
        qoe_sim = {r.rid: r.final_qoe() for r in res_sim.admitted}
        qoe_eng = {r.rid: r.final_qoe() for r in res_eng.admitted}
        ttft_sim = {r.rid: r.final_ttft() for r in res_sim.admitted}
        ttft_eng = {r.rid: r.final_ttft() for r in res_eng.admitted}
        max_dq = max(abs(qoe_sim[rid] - qoe_eng[rid]) for rid in qoe_sim)
        max_dt = max(abs(ttft_sim[rid] - ttft_eng[rid]) for rid in ttft_sim)
        rows.append({
            "name": f"cluster/engine/{router}",
            "avg_qoe_engine": round(res_eng.avg_qoe(), 4),
            "avg_qoe_sim": round(res_sim.avg_qoe(), 4),
            "max_per_request_qoe_delta": round(max_dq, 4),
            "mean_ttft_engine": round(float(res_eng.ttfts().mean()), 4),
            "max_per_request_ttft_delta": round(max_dt, 4),
            "tokens_engine": res_eng.total_tokens(),
            "preemptions_engine": res_eng.preemptions(),
        })
    return rows


def _speculative_sweep(quick: bool):
    """Speculative vs baseline engine replicas on one trace: the lossless
    gate as benchmark rows. Per k, a speculative fleet must emit the
    *identical* per-request token streams as the baseline engine fleet
    (greedy verification is exact) while spending strictly fewer decode
    steps whenever any proposal is accepted; QoE moves with the burst
    delivery shape that pace_delivery smooths back out."""
    import dataclasses

    import jax

    from repro.configs import get_smoke_config
    from repro.core import SpeculativeLatencyModel, TPU_V5E, make_scheduler
    from repro.core.qoe import QoESpec
    from repro.core.request import Request
    from repro.models import Model
    from repro.serving import ServingEngine
    from repro.workload.arrivals import gamma_arrivals

    cfg = get_smoke_config("llama3-8b")   # untied embeddings: varied chains
    model_obj = Model(cfg)
    params = model_obj.init(jax.random.PRNGKey(0))
    # drafts: the target itself (acceptance ceiling) and a perturbed copy
    # (realistic partial agreement); both share the tokenizer/vocab
    perturbed = jax.tree.map(
        lambda a: a + 1e-3 * jax.random.normal(
            jax.random.PRNGKey(9), a.shape, a.dtype), params)
    draft_cfg = dataclasses.replace(cfg, name="llama3-8b-smoke-draft")
    lat = LatencyModel(cfg, TPU_V5E)

    n = 12 if quick else 32
    rng = np.random.default_rng(4)
    arrivals = gamma_arrivals(10.0, n, rng, cv=2.0)
    wl_proto = []
    for i in range(n):
        plen = int(rng.integers(8, 24))
        wl_proto.append(Request(
            rid=i, arrival=float(arrivals[i]), prompt_len=plen,
            output_len=int(rng.integers(10, 20)),
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen),
        ))

    base_wl = [r.clone() for r in wl_proto]
    base = ServingEngine(
        model_obj, params, make_scheduler("andes", 400, lat), lat,
        num_slots=6, max_seq=96, capacity_tokens=400,
    )
    base_res = _serve(base, base_wl)
    base_tokens = {r.rid: r.output_tokens for r in base_wl}

    rows = [{
        "name": "cluster/speculative/baseline",
        "avg_qoe": round(base_res.avg_qoe(), 4),
        "decode_steps": base_res.iterations,
        "tokens": base_res.total_tokens,
    }]
    for draft_name, dparams in (("exact", params), ("perturbed", perturbed)):
        for k in ((2,) if quick else (2, 4)):
            slat = SpeculativeLatencyModel(cfg, TPU_V5E, draft_cfg, k=k)
            spec_wl = [r.clone() for r in wl_proto]
            eng = ServingEngine(
                model_obj, params, make_scheduler("andes", 400, slat), slat,
                num_slots=6, max_seq=96, capacity_tokens=400,
                draft_model=model_obj, draft_params=dparams, spec_k=k,
            )
            res = _serve(eng, spec_wl)
            stats = eng.spec_stats()
            lossless = all(r.output_tokens == base_tokens[r.rid]
                           for r in spec_wl)
            rows.append({
                "name": f"cluster/speculative/draft={draft_name}/k={k}",
                "avg_qoe": round(res.avg_qoe(), 4),
                "decode_steps": res.iterations,
                "step_reduction": round(
                    1.0 - res.iterations / base_res.iterations, 4),
                "tokens": res.total_tokens,
                "acceptance_rate": round(stats["acceptance_rate"], 4),
                "lossless": lossless,
                "fewer_steps": res.iterations < base_res.iterations,
            })
    return rows


def run(quick: bool = False):
    return _router_sweep(quick) + _admission_sweep(quick)


def run_engine(quick: bool = False):
    """Standalone engine-backed mode (python -m benchmarks.cluster_qoe
    --engine). Not part of the default sweep: it initializes a real model
    and is meant as the fleet-level oracle check, not a paper figure."""
    return _engine_sweep(quick)


def run_speculative(quick: bool = False):
    """Standalone speculative mode (python -m benchmarks.cluster_qoe
    --speculative): spec-vs-baseline QoE / decode-step rows with the
    lossless token-identity gate reported per row."""
    return _speculative_sweep(quick)


def validate(rows) -> str:
    d = {r["name"]: r for r in rows}
    checks = []
    ok = True
    for n_replicas in FLEET_POINTS:
        key = f"cluster/replicas={n_replicas}/cv=3.0/derived"
        if key in d:
            dj, dr = d[key]["qoe_minus_jsq"], d[key]["qoe_minus_rr"]
            ok &= dj > 0 and dr > 0
            checks.append(f"r{n_replicas}: qoe-jsq {dj:+.3f} qoe-rr {dr:+.3f}")
    adm = d.get("cluster/admission/derived")
    if adm:
        ok &= adm["shed_served_uplift"] > 0
        checks.append(f"shed uplift {adm['shed_served_uplift']:+.3f}")
    verdict = "OK" if ok else "MISMATCH"
    return (f"{verdict}: QoE router vs jsq/rr at cv=3 gamma "
            f"({'; '.join(checks)}); expected qoe > both and shed uplift > 0")


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None, help="write rows as JSON here")
    ap.add_argument("--engine", action="store_true",
                    help="engine-backed mode: real-model replicas "
                         "(granite smoke config) vs the simulator fleet")
    ap.add_argument("--speculative", action="store_true",
                    help="speculative mode: draft+verify engine replicas "
                         "vs the baseline engine on one trace (lossless "
                         "token-identity gate + step-count reduction)")
    args = ap.parse_args()
    if args.speculative:
        rows = run_speculative(quick=not args.full)
        for r in rows:
            print(r)
        spec_rows = [r for r in rows if "lossless" in r]
        lossless = all(r["lossless"] for r in spec_rows)
        fewer = all(r["fewer_steps"] for r in spec_rows
                    if r["acceptance_rate"] > 0)
        verdict = "OK" if lossless and fewer else "MISMATCH"
        best = max(r["step_reduction"] for r in spec_rows)
        print(f"{verdict}: speculative ≡ baseline token-for-token "
              f"(lossless={lossless}), strictly fewer steps when accepting "
              f"({fewer}), best step reduction {best:.0%}")
    elif args.engine:
        rows = run_engine(quick=not args.full)
        for r in rows:
            print(r)
        dq = [r["max_per_request_qoe_delta"] for r in rows]
        dt = [r["max_per_request_ttft_delta"] for r in rows]
        verdict = ("OK" if all(d < 0.15 for d in dq)
                   and all(d < 0.1 for d in dt) else "MISMATCH")
        print(f"{verdict}: sim-vs-engine fleet agreement, max per-request "
              f"QoE delta {max(dq):.3f} (< 0.15), "
              f"TTFT delta {max(dt):.3f}s (< 0.1)")
    else:
        rows = run(quick=not args.full)
        for r in rows:
            print(r)
        print(validate(rows))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {args.out}")
