"""Fig. 3 — motivation: FCFS p90 TTFT blows up past capacity; server-side
generation speed far exceeds user digest speed (4.8 / 3.3 tok/s)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import metrics_row, run_point

RATES = (1.5, 2.2, 2.8, 3.3, 3.8, 4.3)


def run(quick: bool = False):
    rows = []
    for rate in (RATES[1:] if quick else RATES):
        res = run_point("fcfs", rate, quick=quick)
        m = metrics_row(res)
        # server-side generation speed = observed per-request TDS pre-buffer
        rows.append({
            "name": f"fig03/rate={rate}",
            "ttft_p90_s": round(m["ttft_p90"], 2),
            "gen_speed_tok_s": round(m["tds_p50"], 2),
        })
    return rows


def validate(rows) -> str:
    ttfts = [r["ttft_p90_s"] for r in rows]
    speeds = [r["gen_speed_tok_s"] for r in rows]
    blowup = ttfts[-1] > 20 * max(ttfts[0], 0.1)
    faster = min(speeds[:2]) > 4.8
    return (f"p90 TTFT explodes past capacity: {blowup}; "
            f"gen speed > digest speed at low load: {faster}")


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print(validate(rows))
