"""Fig. 12 — token generation throughput vs request rate: Andes pays <= ~10%
at its operating points (§6.2.3)."""
from __future__ import annotations

from benchmarks.common import run_point

RATES = (2.4, 3.0, 3.6, 4.2)


def run(quick: bool = False):
    rows = []
    drops = []
    for rate in (RATES[:3] if quick else RATES):
        thpt = {}
        for sched in ("fcfs", "andes"):
            res = run_point(sched, rate, quick=quick)
            thpt[sched] = res.throughput()
        drop = 1.0 - thpt["andes"] / max(thpt["fcfs"], 1e-9)
        drops.append(drop)
        rows.append({
            "name": f"fig12/rate={rate}",
            "thpt_fcfs": round(thpt["fcfs"], 1),
            "thpt_andes": round(thpt["andes"], 1),
            "drop_pct": round(100 * drop, 1),
        })
    rows.append({"name": "fig12/derived",
                 "max_drop_pct": round(100 * max(drops), 1)})
    return rows


def validate(rows) -> str:
    return (f"max throughput drop {rows[-1]['max_drop_pct']}% "
            f"(paper: <=10% at operating points)")


if __name__ == "__main__":
    for r in run():
        print(r)
