"""Scheduling-policy arena: policy x adversarial-trace x load sweep.

Referees every policy in `repro.core.policies.SCHEDULERS` (minus the DP
variant, which is the same decision as greedy Andes at fig18-documented
extra cost) on the adversarial multi-tenant traces from
`repro.workload.multitenant` — TokenFlow-style synchronized bursts,
heavy-tail prompt elephants, and a one-greedy-tenant isolation test —
at contended load (KV capacity shrunk so policies actually have to
choose). Every cell runs the deterministic virtual-clock simulator, so
the scoreboard is bit-reproducible across machines and is checked in as
``BENCH_policy_arena.json``; ``make bench-arena`` re-runs the sweep and
validates the artifact WITHOUT rewriting it (``--write`` regenerates).

Scoreboard columns (one row per policy x trace x rate cell, computed by
`repro.core.scoring.fairness_report` + simulator counters):

  avg_qoe          mean final QoE (paper Eq. 1) over finished requests
  min_qoe          worst single request's QoE
  slo_attainment   fraction of requests with QoE >= the 0.9 floor
                   (contract targets honored when a tenant carries one)
  goodput_tok_s    SLO goodput, token-weighted: tokens from requests
                   that met their contract, per second of makespan
  goodput_req_s    SLO goodput, request-weighted (capacity-style)
  jains_index      Jain's index over per-tenant weight-normalized
                   service inside the contention window (1.0 = exact
                   weighted fair shares)
  max_min_service  smallest per-tenant normalized service in that
                   window (the max-min yardstick VTC/WSC optimize)
  preempt_freq     preemptions per request
  throughput       emitted tokens / makespan (virtual tok/s)

Summary rows aggregate each policy across cells (mean avg_qoe etc.).

Gates (deterministic — virtual clock, no wall time):
  1. Andes >= every baseline on sweep-mean avg QoE (the paper's
     headline must survive in-repo competition).
  2. A fairness policy (vtc/wsc) takes the best sweep-mean Jain index
     (the counter-metric goes to the counter-policy — if a QoE policy
     also won fairness the arena would not be discriminating).
  3. Every cell conserves tokens: finished == requested for every
     finished request (the conformance suite pins this per policy;
     here it guards the sweep configs too).

Run via ``make bench-arena`` (validate, no rewrite),
``python -m benchmarks.policy_arena --write`` (regenerate artifact),
``--smoke`` (2-policy x 1-trace mini-sweep for CI, no artifact I/O),
or ``python -m benchmarks.run --only arena`` (CSV rows).
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List

from benchmarks.common import latency_model
from repro.core import SchedulerConfig, make_scheduler
from repro.core.scoring import fairness_report
from repro.serving.simulator import ServingSimulator, SimConfig
from repro.workload import make_adversarial_workload

OUT_JSON = (pathlib.Path(__file__).resolve().parent.parent
            / "BENCH_policy_arena.json")

# Contended deployment point: the OPT-66B latency surface with KV shrunk
# ~5x so a 400-request trace saturates memory and the policies diverge.
KV_CAPACITY = 12_000
POLICIES = ["fcfs", "round_robin", "vtc", "wsc", "burst", "andes"]
BASELINES = [p for p in POLICIES if p != "andes"]
TRACES = ["burst", "heavy_tail", "greedy_tenant"]
RATES = [4.0, 6.0]
N_REQUESTS = 400
SEED = 5
QOE_FLOOR = 0.9
REL_TOL = 1e-6     # artifact validation tolerance (virtual clock is
                   # deterministic; tolerance only absorbs libm drift)


def run_cell(policy: str, trace: str, rate: float, n: int = N_REQUESTS,
             seed: int = SEED) -> Dict[str, float]:
    """One scoreboard cell: run `policy` on `trace` at `rate` req/s."""
    lat = latency_model()
    wl = make_adversarial_workload(trace, n, rate, seed=seed)
    sched = make_scheduler(policy, KV_CAPACITY, lat, SchedulerConfig())
    sim = ServingSimulator(sched, lat,
                           SimConfig(kv_capacity_tokens=KV_CAPACITY))
    res = sim.run([r.clone() for r in wl])
    rep = fairness_report(res.requests, res.makespan,
                          default_floor=QOE_FLOOR)
    assert all(r.generated == r.output_len for r in res.requests), \
        f"token conservation violated: {policy} on {trace}@{rate}"
    row = {"policy": policy, "trace": trace, "rate": rate,
           "preempt_freq": round(res.preemption_freq(), 4),
           "throughput": round(res.throughput(), 2)}
    row.update({k: (round(v, 6) if isinstance(v, float) else v)
                for k, v in rep.items()})
    return row


def run_sweep(policies: List[str] = None, traces: List[str] = None,
              rates: List[float] = None, n: int = N_REQUESTS) -> dict:
    policies = policies or POLICIES
    traces = traces or TRACES
    rates = rates or RATES
    cells = [run_cell(p, t, r, n=n)
             for p in policies for t in traces for r in rates]
    summary = {}
    for p in policies:
        mine = [c for c in cells if c["policy"] == p]
        summary[p] = {
            "avg_qoe": round(sum(c["avg_qoe"] for c in mine) / len(mine), 6),
            "min_qoe": round(min(c["min_qoe"] for c in mine), 6),
            "jains_index": round(
                sum(c["jains_index"] for c in mine) / len(mine), 6),
            "goodput_tok_s": round(
                sum(c["goodput_tok_s"] for c in mine) / len(mine), 6),
            "max_min_service": round(
                min(c["max_min_service"] for c in mine), 6),
            "slo_attainment": round(
                sum(c["slo_attainment"] for c in mine) / len(mine), 6),
            "preempt_freq": round(
                sum(c["preempt_freq"] for c in mine) / len(mine), 6),
        }
    return {
        "config": {"kv_capacity": KV_CAPACITY, "n": n, "seed": SEED,
                   "rates": rates, "traces": traces, "policies": policies,
                   "qoe_floor": QOE_FLOOR},
        "cells": cells,
        "summary": summary,
    }


def gate(report: dict) -> List[str]:
    """Deterministic acceptance gates; returns failure messages."""
    fails = []
    s = report["summary"]
    if "andes" in s:
        for p in s:
            if p != "andes" and s[p]["avg_qoe"] > s["andes"]["avg_qoe"]:
                fails.append(
                    f"gate 1: {p} beats andes on sweep-mean avg QoE "
                    f"({s[p]['avg_qoe']} > {s['andes']['avg_qoe']})")
    fair = [p for p in ("vtc", "wsc") if p in s]
    if fair:
        best = max(s, key=lambda p: s[p]["jains_index"])
        if best not in fair:
            fails.append(
                f"gate 2: fairness crown went to {best} "
                f"(jain={s[best]['jains_index']}), not vtc/wsc")
    return fails


def _close(a, b) -> bool:
    if isinstance(a, float) or isinstance(b, float):
        return abs(float(a) - float(b)) <= REL_TOL * max(
            abs(float(a)), abs(float(b)), 1.0)
    return a == b


def validate_artifact(report: dict) -> List[str]:
    """Compare a fresh sweep against the checked-in scoreboard (never
    rewrites). Virtual-clock determinism makes this near-exact; REL_TOL
    absorbs cross-platform libm differences only."""
    if not OUT_JSON.exists():
        return [f"missing artifact {OUT_JSON.name}; run with --write"]
    pinned = json.loads(OUT_JSON.read_text())
    fails = []
    if pinned.get("config") != report["config"]:
        fails.append("artifact sweep config differs from current code")
    old = {(c["policy"], c["trace"], c["rate"]): c
           for c in pinned.get("cells", [])}
    for c in report["cells"]:
        key = (c["policy"], c["trace"], c["rate"])
        if key not in old:
            fails.append(f"cell {key} missing from artifact")
            continue
        for k, v in c.items():
            if not _close(v, old[key].get(k)):
                fails.append(
                    f"cell {key} drifted on {k}: {old[key].get(k)} -> {v}")
    return fails


def run(quick: bool = True):
    """benchmarks.run integration: CSV rows (one per summary policy)."""
    rep = run_sweep(rates=[6.0] if quick else None,
                    n=300 if quick else N_REQUESTS)
    rows = [{"name": f"arena_{p}", **vals}
            for p, vals in rep["summary"].items()]
    rows.append({"name": "arena_gates",
                 "failures": gate(rep) or "none",
                 "cells": len(rep["cells"])})
    return rows


def validate(rows) -> str:
    by = {r["name"]: r for r in rows}
    fails = by["arena_gates"]["failures"]
    ok = fails == "none"
    andes = by.get("arena_andes", {})
    fair = {p: by[f"arena_{p}"]["jains_index"]
            for p in ("vtc", "wsc") if f"arena_{p}" in by}
    return (f"{'OK' if ok else 'FAIL'}: andes avg_qoe="
            f"{andes.get('avg_qoe')}, fairness jain={fair}, "
            f"gates={'pass' if ok else fails}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--write", action="store_true",
                    help="regenerate the checked-in scoreboard artifact")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mini-sweep: 2 policies x 1 trace x 1 rate, "
                         "gates only, no artifact I/O")
    args = ap.parse_args()

    if args.smoke:
        rep = run_sweep(policies=["fcfs", "andes"], traces=["burst"],
                        rates=[6.0], n=150)
        for c in rep["cells"]:
            print(json.dumps(c))
        fails = gate(rep)
        if fails:
            raise SystemExit("\n".join(fails))
        print("OK: arena smoke gates passed "
              f"(andes avg_qoe={rep['summary']['andes']['avg_qoe']} >= "
              f"fcfs {rep['summary']['fcfs']['avg_qoe']})")
        return

    report = run_sweep()
    for p, vals in report["summary"].items():
        print(f"{p:12s} {json.dumps(vals)}")
    fails = gate(report)
    if args.write:
        OUT_JSON.write_text(json.dumps(report, indent=2) + "\n")
        print(f"wrote {OUT_JSON.name} ({len(report['cells'])} cells)")
    else:
        fails += validate_artifact(report)
    if fails:
        raise SystemExit("\n".join(fails))
    print("OK: gates passed; artifact "
          + ("rewritten" if args.write else "validated without rewrite"))


if __name__ == "__main__":
    main()
