"""Wire-serving benchmark: what the HTTP/SSE frontend costs (PR 9).

Runs an in-process wall-clock ServingServer (the same smoke engine the
standalone `python -m repro.server` boots) under waves of concurrent SSE
streams and measures the wire path end to end:

  * wall tokens/s delivered over the socket (all waves),
  * mean/p95 TTFT and mean QoE as the *client* reconstructs them from
    SSE frames — cross-checked against the engine's own request records
    (the wire must report what the engine did, exactly),
  * the wall-vs-virtual tolerance differential (serving.tolerance) for
    the full population — the same gate the CI smoke job runs per-PR,
    here recorded as a diffable artifact,
  * SSE flush volume (events, bytes) from the server's MetricsRegistry.

Writes ``BENCH_server.json`` at the repo root (like BENCH_hotpath.json —
diffable PR over PR). ``--smoke`` runs one small wave and skips the
artifact write (the CI-friendly variant).

Run:  PYTHONPATH=src python benchmarks/server_bench.py [--smoke]
"""
from __future__ import annotations

import asyncio
import json
import pathlib
import sys
import time

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import QoESpec                                  # noqa: E402
from repro.core.request import ReqState, Request                # noqa: E402
from repro.serving import (Tolerance, ToleranceSpec,            # noqa: E402
                           compare_requests)
from repro.server import (ServerConfig, ServingServer, astream,  # noqa: E402
                          build_engine)

OUT_JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_server.json"
SPEC = QoESpec(ttft=1.0, tds=4.8)
PROMPT_LEN = 9
GATES = ToleranceSpec(
    ttft_mean_diff=Tolerance(abs_tol=0.5),
    ttft_p95_diff=Tolerance(abs_tol=1.0),
    ttft_max_diff=Tolerance(abs_tol=2.0),
    tds_mean_diff=Tolerance(abs_tol=2.0, rel_tol=0.5),
    qoe_mean_diff=Tolerance(abs_tol=0.30),
    qoe_max_diff=Tolerance(abs_tol=0.60),
    qoe_mean_of=Tolerance(abs_tol=0.30),
)


def _prompt(rid: int):
    return np.random.default_rng((7, rid)).integers(
        0, 1 << 14, PROMPT_LEN).tolist()


def _as_request(rid: int, out_len: int, evs) -> Request:
    acc = next(d for k, d in evs if k == "accepted")
    toks = [d for k, d in evs if k == "token"]
    r = Request(rid=rid, arrival=float(acc["arrival"]),
                prompt_len=PROMPT_LEN, output_len=out_len, spec=SPEC)
    r.emit_times = [float(d["t"]) for d in toks]
    r.output_tokens = [int(d["token"]) for d in toks]
    r.generated = len(toks)
    r.state = ReqState.FINISHED
    return r


def run(waves: int = 3, concurrency: int = 8, out_len: int = 12) -> dict:
    srv = ServingServer(ServerConfig(clock="wall", warmup=True))
    port = srv.start()
    cand = []
    t0 = time.monotonic()
    try:
        rid = 0
        for _ in range(waves):
            rids = list(range(rid, rid + concurrency))
            rid += concurrency

            async def wave():
                return await asyncio.gather(*[
                    astream("127.0.0.1", port,
                            {"prompt_tokens": _prompt(i),
                             "max_tokens": out_len, "rid": i})
                    for i in rids])

            for i, evs in zip(rids, asyncio.run(wave())):
                cand.append(_as_request(i, out_len, evs))
        elapsed = time.monotonic() - t0
        reg = srv.registry
        sse_events = reg.value("sse_events_flushed_total")
        sse_bytes = reg.value("sse_bytes_flushed_total")
    finally:
        srv.shutdown(drain=False)

    # the wire must report what the engine did — frame-for-frame
    eng_by = {r.rid: r for r in srv.backend.seen if r.rid >= 0}
    wire_exact = all(
        c.output_tokens == list(eng_by[c.rid].output_tokens)
        and np.allclose(c.emit_times, eng_by[c.rid].emit_times)
        for c in cand)

    # wall-vs-virtual tolerance differential on the whole population
    cfg, ref_eng = build_engine(ServerConfig(clock="virtual"))
    ref = [Request(rid=c.rid, arrival=c.arrival, prompt_len=PROMPT_LEN,
                   output_len=out_len, spec=SPEC,
                   prompt_tokens=np.asarray(_prompt(c.rid), np.int32))
           for c in cand]
    ref_eng.run(ref, max_iterations=20_000)
    rep = compare_requests(ref, cand, GATES)

    n_tokens = sum(r.generated for r in cand)
    ttfts = np.array([r.final_ttft() for r in cand])
    return {
        "n_requests": len(cand),
        "waves": waves,
        "concurrency": concurrency,
        "out_len": out_len,
        "wall_seconds": round(elapsed, 3),
        "wire_tokens_per_s": round(n_tokens / elapsed, 1),
        "ttft_mean_s": round(float(ttfts.mean()), 4),
        "ttft_p95_s": round(float(np.percentile(ttfts, 95)), 4),
        "qoe_mean": round(float(np.mean([r.final_qoe() for r in cand])), 4),
        "sse_events_flushed": int(sse_events),
        "sse_bytes_flushed": int(sse_bytes),
        "wire_matches_engine": bool(wire_exact),
        "tolerance_ok": bool(rep.ok),
        "tolerance_gates": {g.name: round(g.cand, 6) for g in rep.gates},
    }


def main() -> None:
    smoke = "--smoke" in sys.argv[1:]
    report = run(waves=1 if smoke else 3, concurrency=8,
                 out_len=8 if smoke else 12)
    print(json.dumps(report, indent=2))
    if not report["wire_matches_engine"]:
        raise SystemExit("SSE stream diverged from engine records")
    if not report["tolerance_ok"]:
        raise SystemExit("wall-vs-virtual tolerance gates failed")
    if not smoke:
        OUT_JSON.write_text(json.dumps(report, indent=2) + "\n")
        print(f"wrote {OUT_JSON.name}")


if __name__ == "__main__":
    main()
