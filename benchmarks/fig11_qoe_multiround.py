"""Fig. 11 — average QoE vs request rate on Multi-Round ShareGPT
(3x longer inputs; §6.2: Andes gains up to 3.2x QoE, 1.1-1.3x capacity)."""
from __future__ import annotations

from benchmarks import fig10_qoe_sharegpt as fig10

RATES = (1.6, 2.0, 2.4, 2.8, 3.2)


def run(quick: bool = False):
    old = fig10.RATES
    fig10.RATES = RATES
    try:
        rows = fig10.run(quick=quick, dataset="multiround")
    finally:
        fig10.RATES = old
    for r in rows:
        r["name"] = r["name"].replace("fig10", "fig11")
    return rows


def validate(rows) -> str:
    d = rows[-1]
    return (f"multi-round capacity ratio {d['capacity_ratio']}x "
            f"(paper: 1.1-1.3x); max QoE gain {d['max_qoe_gain']}x")


if __name__ == "__main__":
    for r in run():
        print(r)
