"""Engine hot-path benchmark: legacy vs optimized serving loop (PR 5).

Measures what the HotpathConfig optimizations buy on a real ServingEngine
driving a mixed-length ShareGPT-style trace on the CPU smoke config —
wall-clock tokens/s, prefill compile count (distinct jit signatures), and
host↔device sync rounds — and gates the comparison on LOSSLESSNESS. This
is the repo's first perf-trajectory artifact: every run writes
``BENCH_hotpath.json`` next to the repo root so the numbers are diffable
PR over PR.

Three variants, two gates:

  * ``legacy``    — the pre-PR-5 hot path (eager exact-length batch-1
                    prefill, full-logit host argmax, one iteration per
                    dispatch).
  * ``reference`` — bucketed prefill only; sampling and stepping as in
                    legacy. Same prefill numerics as ``optimized``.
  * ``optimized`` — everything on (the engine default).

Gate 1 (exact): ``optimized`` must reproduce ``reference`` bit-for-bit —
token ids, emission timestamps, preemptions, final QoE — because fused
sampling and multi-step decode are bit-identical transformations of the
single-step loop (pinned in tests/test_hotpath.py). Gate 2 (vs legacy):
emission timestamps, token counts, preemptions, and QoE must be EXACT
(the virtual clock prices real lengths, never padded ones), while token
ids are reported as an agreement count: padded lengths-masked prefill is
mathematically equivalent to exact-length prefill but not bitwise equal
(last-ulp reduction differences), so a greedy near-tie in the random
smoke model can flip — e.g. 45/50 requests token-identical on the default
trace, every flip traced to a logit gap below 1e-5. A trained model's
argmax margins make this a non-event; the repo's own differential suites
(which share one prefill path) are the real losslessness authority.

Metrics: cold tokens/s (first run, compiles included — what a fresh
server pays; bucketing bounds it), warm tokens/s (second run, compile
caches warm — the >= 2x gate), prefill_compiles (distinct prefill shape
signatures: one per distinct prompt length for legacy, <= #length-buckets
x #row-buckets bucketed), host_syncs (device->host rounds per run;
multi-step decode divides the decode share by ~j).

Since PR 6 the counters come from the observability layer: every variant
runs with a ``ProfilingObserver`` attached (uniform across variants, so
speedup ratios stay fair) and syncs/dispatches/compiles are read from its
``MetricsRegistry`` — cross-checked against the engine's private counters
so the two surfaces can never drift. A final *observability* section
measures what full instrumentation (trace + metrics + profiling) costs on
the optimized engine: the instrumented run must be bit-identical, its
trace must reconcile to the reported QoE, and its warm throughput must be
within ``OBS_OVERHEAD_GATE_PCT`` of the uninstrumented engine
(best-of-``OBS_REPS`` alternating timing to de-noise shared runners).

Since PR 8 token flips vs legacy are not merely counted but AUDITED:
every first-divergence position is re-priced by the exact-length model
(`repro.serving.lossless`) and the run fails unless all flips hide
behind a sub-``FLIP_TOL`` top-2 logit margin — the documented-ulp-flip
claim above is a gate, not a comment. A separate **scale** section
(``--scale``, ``make bench-scale``; ``--scale --smoke`` for the CI-sized
variant) drives a 1000-request heavy-tail trace through a fixed-slot
engine and a paged+chunked one at EQUAL KV-token capacity and gates
paged tokens/s >= fixed-slot with strictly lower worst-case TTFT; the
full run read-modify-writes the ``scale`` key of ``BENCH_hotpath.json``.

Run via ``python -m benchmarks.run --only hotpath`` (CSV rows like every
figure module), ``python -m benchmarks.engine_hotpath`` standalone,
``make bench-hotpath``, or ``python -m benchmarks.engine_hotpath --obs``
(``make bench-obs``: observability section only, no JSON rewrite).
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import LatencyModel, QoESpec, SchedulerConfig, TPU_V5E, make_scheduler
from repro.models import Model
from repro.obs import (MetricsObserver, MetricsRegistry, ProfilingObserver,
                       TraceRecorder, compose, qoe_from_trace)
from repro.serving import HotpathConfig, Request, ServingEngine
from repro.serving.lossless import (FLIP_TOL, all_flips_documented,
                                    audit_flips, fingerprint,
                                    timing_fingerprint)

ARCH = "llama3-8b"
NUM_SLOTS = 8
MAX_SEQ = 96
OUT_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_hotpath.json"

# ---- scale section (PR 8): chunked prefill + paged KV at 1000 requests ----
# Both variants get the SAME KV token budget; the fixed-slot engine must
# reserve max_seq depth per slot, so equal capacity buys it only 16
# residents, while the paged engine slices the budget into 64-token pages
# across 64 slots and chunks long prefills so they can't monopolize an
# iteration. The heavy-tail trace (95% short prompts, 5% near-max) is the
# adversarial case: under fixed slots the long prompts both queue behind
# slot scarcity and stall everyone's decode for a monolithic prefill.
SCALE_N = 1000
SCALE_SMOKE_N = 200
SCALE_MAX_SEQ = 256
SCALE_CAPACITY = 16 * SCALE_MAX_SEQ          # shared KV token budget (4096)
SCALE_FIXED_SLOTS = 16                       # 4096 / max_seq — reservation-bound
SCALE_PAGED_SLOTS = 64
SCALE_PAGE = 64
SCALE_CHUNK = 64

# ---- physical paging section (PR 10): device page pool + persistent loop --
# Page x chunk sweep on a contended pool (capacity < slots*max_seq, so the
# physical pool — not slot count — is the binding admission resource).
# Per combo the physically paged engine must (a) reproduce the
# accounting-only engine bit-for-bit and (b) match or beat its virtual
# tokens/s; one combo additionally pins the persistent while_loop's sync
# count strictly below the static-scan multi-step engine's (unquantized j
# fuses at least as many iterations per dispatch).
PHYS_N = 300
PHYS_SMOKE_N = 80
PHYS_MAX_SEQ = 96
PHYS_SLOTS = 16
PHYS_CAPACITY = PHYS_SLOTS * 64
PHYS_SWEEP = ((16, 0), (32, 48), (64, 0))    # (page_size, prefill_chunk)
OBS_OVERHEAD_GATE_PCT = 4.0    # full instrumentation may cost at most this.
                               # The observer cost is a fixed per-event Python
                               # tax, so the PERCENTAGE scales with how fast
                               # the base engine runs on the host: the same
                               # code measures ~1.3% at ~650 tok/s and
                               # ~1.6-2.9% at ~1350 tok/s. The gate bounds
                               # the tax at twice the fast-host ceiling.
OBS_REPS = 7                   # best-of-N warm timings per side: warm runs
                               # are ~0.5 s, so extra reps are cheap, and the
                               # gate needs the min-wall floor estimate to
                               # converge on a shared/noisy machine


def sharegpt_style_trace(cfg, n: int, seed: int = 0):
    """Mixed-length trace shaped like the paper's ShareGPT marginals
    (lognormal-ish prompt lengths, wide output spread), scaled into the
    smoke engine's max_seq budget. Real token ids — this drives actual
    prefills, not length placeholders."""
    rng = np.random.default_rng(seed)
    wl = []
    t = 0.0
    for i in range(n):
        t += float(rng.exponential(0.08))
        plen = int(np.clip(rng.lognormal(mean=3.0, sigma=0.6), 6, 72))
        out = int(rng.integers(12, 40))
        wl.append(Request(
            rid=i, arrival=t, prompt_len=plen, output_len=out,
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
        ))
    return wl


def clone(wl):
    return [r.clone() for r in wl]


def mk_engine(model, params, lat, hotpath: HotpathConfig) -> ServingEngine:
    cap = NUM_SLOTS * MAX_SEQ
    sched = make_scheduler("andes", cap, lat, SchedulerConfig())
    return ServingEngine(model, params, sched, lat, num_slots=NUM_SLOTS,
                         max_seq=MAX_SEQ, capacity_tokens=cap,
                         hotpath=hotpath)


def _timed_run(eng: ServingEngine, wl):
    t0 = time.perf_counter()
    out = eng.run(clone(wl), max_iterations=50_000)
    jax.block_until_ready(eng.cache["length"])
    return out, time.perf_counter() - t0


# losslessness fingerprints + flip classification live in
# repro.serving.lossless (single owner; the pinned near-tie test in
# tests/test_lossless_flips.py exercises the same classifier)
_fingerprint = fingerprint
_timing_fingerprint = timing_fingerprint


def _hotpath_counters(reg: MetricsRegistry) -> dict:
    """Point-in-time registry totals for the hot-path counters."""
    return {
        "host_syncs": int(reg.value("engine_host_syncs_total")),
        "dispatches": int(sum(v for _, labels, v
                              in reg.get("engine_dispatches_total").samples())),
        "jit_compiles": int(reg.value("engine_jit_compiles_total")),
        "multi_step_blocks": int(reg.value("engine_multi_step_blocks_total")),
    }


def _registry_run_stats(reg: MetricsRegistry, before: dict) -> dict:
    """One run's counts from accumulating registry totals: syncs/dispatches
    /multi-step deltas since `before`; compiles as totals (shape signatures
    fire once per engine lifetime — all on the cold run, by design)."""
    now = _hotpath_counters(reg)
    return {
        "host_syncs": now["host_syncs"] - before["host_syncs"],
        "dispatches": now["dispatches"] - before["dispatches"],
        "multi_step_blocks": (now["multi_step_blocks"]
                              - before["multi_step_blocks"]),
        "jit_compiles": now["jit_compiles"],
    }


def _cross_check_registry(stats: dict, eng: ServingEngine) -> None:
    """The registry and the engine's private counters must agree exactly —
    the whole point of routing benchmarks through the observability layer
    is that the two surfaces cannot drift."""
    hs = eng.hotpath_stats()
    for reg_key, eng_key in (("host_syncs", "host_syncs"),
                             ("dispatches", "dispatches"),
                             ("multi_step_blocks", "multi_step_blocks"),
                             ("jit_compiles", "prefill_compiles")):
        if stats[reg_key] != hs[eng_key]:
            raise SystemExit(
                f"metrics registry disagrees with engine counters: "
                f"{reg_key}={stats[reg_key]} vs engine {eng_key}={hs[eng_key]}")


def observability_section(model, params, lat, wl, reps: int = OBS_REPS) -> dict:
    """Cost and correctness of FULL instrumentation on the optimized engine.

    Two engines — one bare, one with trace + metrics + profiling attached —
    alternate warm timed runs (best-of-`reps` each, so a load spike on a
    shared runner hits both sides). Gates: instrumented output bit-identical
    to bare; QoE recomputed purely from the trace equals the engine-reported
    QoE; registry counters equal the engine's private ones; throughput
    overhead within OBS_OVERHEAD_GATE_PCT."""
    bare = mk_engine(model, params, lat, HotpathConfig())
    inst = mk_engine(model, params, lat, HotpathConfig())
    trace = TraceRecorder()
    reg = MetricsRegistry()
    inst.observer = compose(trace, MetricsObserver(reg),
                            ProfilingObserver(reg))

    _timed_run(bare, wl)            # cold (compiles) — untimed for the gate
    _timed_run(inst, wl)
    bare_walls, inst_walls = [], []
    bare_out = inst_out = None
    before = None
    for _ in range(reps):
        bare_out, w = _timed_run(bare, wl)
        bare_walls.append(w)
        trace.clear()               # keep exactly one run's events
        before = _hotpath_counters(reg)
        inst_out, w = _timed_run(inst, wl)
        inst_walls.append(w)

    tokens = sum(r.generated for r in inst_out)
    bit_identical = _fingerprint(inst_out) == _fingerprint(bare_out)
    traced_qoe = qoe_from_trace(trace.events)
    qoe_reconciled = all(traced_qoe.get(r.rid, 0.0) == r.final_qoe()
                         for r in inst_out)
    run_stats = _registry_run_stats(reg, before)
    _cross_check_registry(run_stats, inst)

    wall_off, wall_on = min(bare_walls), min(inst_walls)
    overhead_pct = 100.0 * (wall_on - wall_off) / wall_off
    return {
        "tok_per_s_off": round(tokens / wall_off, 1),
        "tok_per_s_instrumented": round(tokens / wall_on, 1),
        "overhead_pct": round(overhead_pct, 2),
        "overhead_gate_pct": OBS_OVERHEAD_GATE_PCT,
        "timing": f"best-of-{reps}, alternating",
        "bit_identical": bool(bit_identical),
        "qoe_reconciled_from_trace": bool(qoe_reconciled),
        "registry_matches_engine": True,      # _cross_check_registry raised otherwise
        "trace_events_per_run": len(trace.events),
        "counters_per_run": run_stats,
    }


def run(quick: bool = True):
    n = 50 if quick else 200
    cfg = get_smoke_config(ARCH)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lat = LatencyModel(cfg, TPU_V5E)
    wl = sharegpt_style_trace(cfg, n)
    n_lengths = len({r.prompt_len for r in wl})

    variants = {
        "legacy": HotpathConfig.baseline(),
        "reference": HotpathConfig(prefill_buckets=True,
                                   fused_sampling=False, multi_step=1),
        "optimized": HotpathConfig(),
    }
    res, outs = {}, {}
    for name, hp in variants.items():
        eng = mk_engine(model, params, lat, hp)
        # every variant carries the same profiling-only observer, so the
        # counters come from the metrics registry (cross-checked against
        # the engine's private ones) and speedup ratios stay apples-to-
        # apples; full-instrumentation cost is measured separately below
        reg = MetricsRegistry()
        eng.observer = ProfilingObserver(reg)
        out_cold, wall_cold = _timed_run(eng, wl)
        after_cold = _hotpath_counters(reg)
        out_warm, wall_warm = _timed_run(eng, wl)
        # registry totals accumulate cold+warm; warm-run deltas ARE one
        # run's counts (compiles all land on the cold run, reported total)
        stats = _registry_run_stats(reg, after_cold)
        _cross_check_registry(stats, eng)
        tokens = sum(r.generated for r in out_warm)
        outs[name] = out_warm
        res[name] = {
            "wall_s_cold": round(wall_cold, 3),
            "wall_s_warm": round(wall_warm, 3),
            "tokens": tokens,
            "tok_per_s_cold": round(tokens / wall_cold, 1),
            "tok_per_s_warm": round(tokens / wall_warm, 1),
            "prefill_compiles": stats["jit_compiles"],
            "host_syncs_per_run": stats["host_syncs"],
            "dispatches_per_run": stats["dispatches"],
            "multi_step_blocks": stats["multi_step_blocks"],
            "kv_peak_util": round(eng.kv.peak_utilization, 3),
            "iterations": eng.iterations,
            "counter_source": "metrics_registry",
        }
        if name == "optimized":
            hs = eng.hotpath_stats()
            res[name]["bucket_grid"] = hs["prefill_bucket_grid"]
            res[name]["prefill_shapes"] = [list(s) for s in
                                           hs["prefill_shapes"]]

    legacy, ref, opt = res["legacy"], res["reference"], res["optimized"]
    # gate 1: exact — fused sampling + multi-step are bit-identical
    lossless_exact = _fingerprint(outs["optimized"]) == \
        _fingerprint(outs["reference"])
    # gate 2: timing-exact vs the pre-PR-5 engine; token ids may flip on
    # greedy near-ties (padded-vs-exact prefill ulps — module docstring)
    lossless_timing = _timing_fingerprint(outs["optimized"]) == \
        _timing_fingerprint(outs["legacy"])
    token_identical = sum(
        a.output_tokens == b.output_tokens
        for a, b in zip(outs["optimized"], outs["legacy"]))
    # every flip must be a DOCUMENTED ulp flip: recompute the exact-length
    # top-2 logit margin at each first-divergence point and require it
    # under FLIP_TOL (repro.serving.lossless owns the classification)
    flips = audit_flips(model, params, outs["optimized"], outs["legacy"])
    flips_documented = all_flips_documented(flips)

    speedup_warm = opt["tok_per_s_warm"] / legacy["tok_per_s_warm"]
    speedup_cold = opt["tok_per_s_cold"] / legacy["tok_per_s_cold"]
    n_buckets = (len(opt["bucket_grid"])
                 * len({s[0] for s in opt["prefill_shapes"]}))

    obs = observability_section(model, params, lat, wl)

    report = {
        "arch": ARCH,
        "trace": {"n": n, "distinct_prompt_lengths": n_lengths,
                  "max_seq": MAX_SEQ, "num_slots": NUM_SLOTS},
        "lossless_exact_vs_reference": bool(lossless_exact),
        "lossless_timing_vs_legacy": bool(lossless_timing),
        "token_identical_vs_legacy": f"{token_identical}/{n}",
        "token_flips": [{**f, "margin": float(f"{f['margin']:.3e}")}
                        for f in flips],
        "flips_documented": bool(flips_documented),
        "flip_tolerance": FLIP_TOL,
        "speedup_warm": round(speedup_warm, 2),
        "speedup_cold": round(speedup_cold, 2),
        "sync_reduction": round(legacy["host_syncs_per_run"]
                                / max(opt["host_syncs_per_run"], 1), 2),
        "prefill_compiles": {"legacy": legacy["prefill_compiles"],
                             "optimized": opt["prefill_compiles"],
                             "bucket_bound": n_buckets},
        "observability": obs,
        "legacy": legacy,
        "reference": ref,
        "optimized": opt,
    }
    # read-modify-write: the scale section (bench-scale, nightly) lives in
    # the same artifact and must survive a hot-path rewrite (and vice versa)
    if OUT_JSON.exists():
        try:
            prev = json.loads(OUT_JSON.read_text())
            if "scale" in prev:
                report["scale"] = prev["scale"]
        except (json.JSONDecodeError, OSError):
            pass
    OUT_JSON.write_text(json.dumps(report, indent=2) + "\n")

    rows = [
        {"name": "hotpath_legacy",
         "tok_per_s_warm": legacy["tok_per_s_warm"],
         "tok_per_s_cold": legacy["tok_per_s_cold"],
         "prefill_compiles": legacy["prefill_compiles"],
         "host_syncs": legacy["host_syncs_per_run"]},
        {"name": "hotpath_optimized",
         "tok_per_s_warm": opt["tok_per_s_warm"],
         "tok_per_s_cold": opt["tok_per_s_cold"],
         "prefill_compiles": opt["prefill_compiles"],
         "host_syncs": opt["host_syncs_per_run"],
         "multi_step_blocks": opt["multi_step_blocks"]},
        {"name": "hotpath_observability",
         "tok_per_s_off": obs["tok_per_s_off"],
         "tok_per_s_instrumented": obs["tok_per_s_instrumented"],
         "overhead_pct": obs["overhead_pct"],
         "bit_identical": obs["bit_identical"],
         "qoe_reconciled": obs["qoe_reconciled_from_trace"],
         "trace_events": obs["trace_events_per_run"]},
        {"name": "hotpath_summary",
         "lossless_exact": lossless_exact,
         "lossless_timing": lossless_timing,
         "token_identical": f"{token_identical}/{n}",
         "flips_documented": flips_documented,
         "speedup_warm": round(speedup_warm, 2),
         "speedup_cold": round(speedup_cold, 2),
         "obs_overhead_pct": obs["overhead_pct"],
         "json": str(OUT_JSON.name)},
    ]
    return rows


def validate(rows) -> str:
    by = {r["name"]: r for r in rows}
    s = by["hotpath_summary"]
    legacy, opt = by["hotpath_legacy"], by["hotpath_optimized"]
    obs = by["hotpath_observability"]
    ok_lossless = (s["lossless_exact"] and s["lossless_timing"]
                   and s["flips_documented"])
    # pass/fail mirrors main()'s CI gate (>= legacy — wall clock is
    # load-sensitive on shared runners); the 2x target is reported
    # separately and recorded by the checked-in BENCH_hotpath.json
    ok_speed = s["speedup_warm"] >= 1.0
    ok_compiles = opt["prefill_compiles"] < legacy["prefill_compiles"]
    ok_obs = (obs["bit_identical"] and obs["qoe_reconciled"]
              and obs["overhead_pct"] <= OBS_OVERHEAD_GATE_PCT)
    ok = ok_lossless and ok_speed and ok_compiles and ok_obs
    target = "met" if s["speedup_warm"] >= 2.0 else "NOT met (loaded host?)"
    return (f"{'OK' if ok else 'FAIL'}: exact-vs-ref={s['lossless_exact']}, "
            f"timing-vs-legacy={s['lossless_timing']}, "
            f"tokens-vs-legacy {s['token_identical']}, "
            f"warm speedup {s['speedup_warm']}x (2x target {target}), "
            f"prefill compiles {legacy['prefill_compiles']} -> "
            f"{opt['prefill_compiles']}, "
            f"syncs {legacy['host_syncs']} -> {opt['host_syncs']}, "
            f"obs overhead {obs['overhead_pct']}% "
            f"(gate {OBS_OVERHEAD_GATE_PCT}%, "
            f"bit-identical={obs['bit_identical']}, "
            f"trace-QoE-reconciled={obs['qoe_reconciled']})")


def _gate_observability(obs: dict) -> None:
    """CI gates for the instrumentation cost/correctness section.
    Correctness gates are deterministic and absolute; the overhead gate is
    best-of-N alternating timing, so a load spike hits both sides."""
    if not obs["bit_identical"]:
        raise SystemExit("instrumented engine is not bit-identical")
    if not obs["qoe_reconciled_from_trace"]:
        raise SystemExit("trace-reconstructed QoE != engine-reported QoE")
    if obs["overhead_pct"] > OBS_OVERHEAD_GATE_PCT:
        raise SystemExit(
            f"observability overhead {obs['overhead_pct']}% exceeds "
            f"{OBS_OVERHEAD_GATE_PCT}% gate")


def heavy_tail_trace(cfg, n: int, seed: int = 7):
    """The scale section's adversarial trace: a tight arrival stream of
    mostly-short prompts with a 5% heavy tail near max_seq. Fixed-slot
    serving suffers twice on it — long prompts queue behind slot
    scarcity, then stall every resident's decode for one monolithic
    prefill — which is exactly what paging + chunking dissolve."""
    rng = np.random.default_rng(seed)
    wl = []
    t = 0.0
    for i in range(n):
        # ~250 req/s offered load: well past what 16 reservation-bound
        # slots can drain, so a queue forms and slot scarcity (not service
        # time) dominates fixed-slot TTFT — the regime paging exists for
        t += float(rng.exponential(0.004))
        if rng.random() < 0.05:
            plen = int(rng.integers(160, SCALE_MAX_SEQ - 33))
        else:
            plen = int(rng.integers(6, 24))
        out = int(rng.integers(8, 32))
        wl.append(Request(
            rid=i, arrival=t, prompt_len=plen, output_len=out,
            spec=QoESpec(ttft=1.0, tds=4.8),
            prompt_tokens=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
        ))
    return wl


def _scale_variant(model, params, lat, wl, *, num_slots: int,
                   page_size=None, prefill_chunk: int = 0) -> dict:
    sched = make_scheduler("andes", SCALE_CAPACITY, lat, SchedulerConfig())
    eng = ServingEngine(model, params, sched, lat, num_slots=num_slots,
                        max_seq=SCALE_MAX_SEQ,
                        capacity_tokens=SCALE_CAPACITY,
                        page_size=page_size, prefill_chunk=prefill_chunk)
    t0 = time.perf_counter()
    out = eng.run(clone(wl), max_iterations=500_000)
    jax.block_until_ready(eng.cache["length"])
    wall = time.perf_counter() - t0
    unfinished = sum(r.generated < r.output_len for r in out)
    tokens = sum(r.generated for r in out)
    ttfts = [r.final_ttft() for r in out if r.emit_times]
    occ = eng.kv.occupancy()
    return {
        "num_slots": num_slots,
        "page_size": occ["page_size"] if occ["paged"] else None,
        "prefill_chunk": prefill_chunk or None,
        "capacity_tokens": SCALE_CAPACITY,
        "tokens": tokens,
        "unfinished": unfinished,
        "wall_s": round(wall, 2),
        "tok_per_s_wall": round(tokens / wall, 1),
        # the deterministic throughput figure: virtual seconds are priced
        # by the roofline LatencyModel, so this is load-insensitive and
        # is what the CI gate compares
        "virtual_s": round(eng.now, 3),
        "tok_per_s_virtual": round(tokens / eng.now, 1),
        "ttft_worst_s": round(max(ttfts), 3),
        "ttft_mean_s": round(float(np.mean(ttfts)), 3),
        "preemptions": eng.preemptions,
        "kv_peak_util": round(eng.kv.peak_utilization, 3),
        "kv_peak_pages": occ.get("peak_pages_used", None),
        "iterations": eng.iterations,
    }


def scale_section(n: int) -> dict:
    """Fixed-slot vs paged+chunked at EQUAL KV-token capacity on the
    heavy-tail trace. Gates (deterministic, virtual-clock):
    paged tokens/s >= fixed-slot AND strictly lower worst-case TTFT."""
    cfg = get_smoke_config(ARCH)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lat = LatencyModel(cfg, TPU_V5E)
    wl = heavy_tail_trace(cfg, n)

    fixed = _scale_variant(model, params, lat, wl,
                           num_slots=SCALE_FIXED_SLOTS)
    paged = _scale_variant(model, params, lat, wl,
                           num_slots=SCALE_PAGED_SLOTS,
                           page_size=SCALE_PAGE, prefill_chunk=SCALE_CHUNK)
    n_long = sum(r.prompt_len >= 160 for r in wl)
    return {
        "trace": {"n": n, "long_prompts": n_long,
                  "max_seq": SCALE_MAX_SEQ, "seed": 7},
        "fixed_slot": fixed,
        "paged_chunked": paged,
        "throughput_ratio": round(paged["tok_per_s_virtual"]
                                  / fixed["tok_per_s_virtual"], 2),
        "ttft_worst_ratio": round(paged["ttft_worst_s"]
                                  / fixed["ttft_worst_s"], 3),
        "gate_throughput": paged["tok_per_s_virtual"]
        >= fixed["tok_per_s_virtual"],
        "gate_worst_ttft": paged["ttft_worst_s"] < fixed["ttft_worst_s"],
    }


def _gate_scale(sc: dict) -> None:
    if sc["fixed_slot"]["unfinished"] or sc["paged_chunked"]["unfinished"]:
        raise SystemExit("scale trace did not fully drain")
    if not sc["gate_throughput"]:
        raise SystemExit(
            "paged+chunked engine below fixed-slot throughput at equal "
            f"capacity: {sc['paged_chunked']['tok_per_s_virtual']} < "
            f"{sc['fixed_slot']['tok_per_s_virtual']} tok/s (virtual)")
    if not sc["gate_worst_ttft"]:
        raise SystemExit(
            "paged+chunked engine did not improve worst-case TTFT: "
            f"{sc['paged_chunked']['ttft_worst_s']}s vs fixed-slot "
            f"{sc['fixed_slot']['ttft_worst_s']}s")


def run_scale(smoke: bool = False) -> None:
    """`--scale [--smoke]` / `make bench-scale[-smoke]`: the 100x-scale
    section. The full run (nightly) read-modify-writes the `scale` key of
    BENCH_hotpath.json; the smoke run gates only, no artifact rewrite."""
    n = SCALE_SMOKE_N if smoke else SCALE_N
    sc = scale_section(n)
    print(json.dumps(sc, indent=2))
    _gate_scale(sc)
    if not smoke:
        report = json.loads(OUT_JSON.read_text()) if OUT_JSON.exists() else {}
        report["scale"] = sc
        OUT_JSON.write_text(json.dumps(report, indent=2) + "\n")
        print(f"wrote scale section to {OUT_JSON.name}")
    print(f"OK: paged+chunked {sc['throughput_ratio']}x tokens/s, "
          f"worst TTFT {sc['ttft_worst_ratio']}x of fixed-slot "
          f"({n} requests, equal {SCALE_CAPACITY}-token capacity)")


def _phys_variant(model, params, lat, wl, *, page_size: int,
                  prefill_chunk: int = 0, physical: bool = True,
                  hotpath=None) -> dict:
    sched = make_scheduler("andes", PHYS_CAPACITY, lat, SchedulerConfig())
    eng = ServingEngine(model, params, sched, lat, num_slots=PHYS_SLOTS,
                        max_seq=PHYS_MAX_SEQ,
                        capacity_tokens=PHYS_CAPACITY, page_size=page_size,
                        prefill_chunk=prefill_chunk,
                        physical_pages=physical, hotpath=hotpath)
    t0 = time.perf_counter()
    out = eng.run(clone(wl), max_iterations=500_000)
    jax.block_until_ready(eng.cache["length"])
    wall = time.perf_counter() - t0
    tokens = sum(r.generated for r in out)
    return {
        "page_size": page_size,
        "prefill_chunk": prefill_chunk or None,
        "physical": eng.physical_pages,
        "tokens": tokens,
        "unfinished": sum(r.generated < r.output_len for r in out),
        "wall_s": round(wall, 2),
        "tok_per_s_wall": round(tokens / wall, 1),
        "tok_per_s_virtual": round(tokens / eng.now, 1),
        "host_syncs": eng.host_syncs,
        "persistent_blocks": eng.persistent_blocks,
        "persistent_iters": eng.persistent_iters,
        "page_scatters": eng.page_scatters,
        "page_gathers": eng.page_gathers,
        "preemptions": eng.preemptions,
        "_fp": fingerprint(out),
    }


def physical_section(n: int) -> dict:
    """Physically paged cache + persistent device loop vs the
    accounting-only layout, per (page_size, prefill_chunk) combo. Gates:
    bit-identical outputs (the layout moves bytes, never tokens or
    timestamps), physical virtual tokens/s >= accounting-only, and — on
    the first combo — persistent-loop host syncs strictly below the
    static-scan multi-step engine's."""
    cfg = get_smoke_config(ARCH)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lat = LatencyModel(cfg, TPU_V5E)
    wl = sharegpt_style_trace(cfg, n, seed=3)
    for r in wl:
        # the physical pool enforces the context budget the contiguous
        # layout only clamps: keep every request inside max_seq
        r.output_len = min(r.output_len, PHYS_MAX_SEQ - r.prompt_len)

    combos = []
    for page, chunk in PHYS_SWEEP:
        phys = _phys_variant(model, params, lat, wl,
                             page_size=page, prefill_chunk=chunk)
        acct = _phys_variant(model, params, lat, wl,
                             page_size=page, prefill_chunk=chunk,
                             physical=False)
        combos.append({
            "physical": phys, "accounting": acct,
            "gate_bit_identical": phys.pop("_fp") == acct.pop("_fp"),
            "gate_throughput": phys["tok_per_s_virtual"]
            >= acct["tok_per_s_virtual"],
        })
    page0, chunk0 = PHYS_SWEEP[0]
    scan = _phys_variant(model, params, lat, wl, page_size=page0,
                         prefill_chunk=chunk0,
                         hotpath=HotpathConfig(persistent=False))
    scan.pop("_fp")
    persist = combos[0]["physical"]
    return {
        "trace": {"n": n, "max_seq": PHYS_MAX_SEQ, "slots": PHYS_SLOTS,
                  "capacity_tokens": PHYS_CAPACITY, "seed": 3},
        "combos": combos,
        "scan_baseline": scan,
        "gate_persistent_syncs": persist["host_syncs"] < scan["host_syncs"],
    }


def _gate_physical(ph: dict) -> None:
    for c in ph["combos"]:
        tag = (f"page={c['physical']['page_size']} "
               f"chunk={c['physical']['prefill_chunk']}")
        if c["physical"]["unfinished"] or c["accounting"]["unfinished"]:
            raise SystemExit(f"physical trace did not fully drain ({tag})")
        if not c["physical"]["physical"]:
            raise SystemExit(f"physical engine fell back to accounting "
                             f"layout ({tag})")
        if not c["gate_bit_identical"]:
            raise SystemExit(
                f"physically paged engine diverged from accounting-only "
                f"({tag}): the page pool moved a token or a timestamp")
        if not c["gate_throughput"]:
            raise SystemExit(
                f"physical paging slowed the virtual clock ({tag}): "
                f"{c['physical']['tok_per_s_virtual']} < "
                f"{c['accounting']['tok_per_s_virtual']} tok/s")
        if not c["physical"]["persistent_blocks"]:
            raise SystemExit(f"persistent loop never engaged ({tag})")
    if not ph["gate_persistent_syncs"]:
        raise SystemExit(
            "persistent while_loop did not reduce host syncs below the "
            f"static scan: {ph['combos'][0]['physical']['host_syncs']} vs "
            f"{ph['scan_baseline']['host_syncs']}")


def run_physical(smoke: bool = False) -> None:
    """`--physical [--smoke]` / `make bench-physical[-smoke]`: the
    physically-paged-pool + persistent-loop section. The full run
    (nightly) read-modify-writes the `physical_paging` key of
    BENCH_hotpath.json; the smoke run gates only, no artifact rewrite."""
    n = PHYS_SMOKE_N if smoke else PHYS_N
    ph = physical_section(n)
    print(json.dumps(ph, indent=2))
    _gate_physical(ph)
    if not smoke:
        report = json.loads(OUT_JSON.read_text()) if OUT_JSON.exists() else {}
        report["physical_paging"] = ph
        OUT_JSON.write_text(json.dumps(report, indent=2) + "\n")
        print(f"wrote physical_paging section to {OUT_JSON.name}")
    p0 = ph["combos"][0]["physical"]
    print(f"OK: physical pool bit-identical across {len(ph['combos'])} "
          f"page/chunk combos; persistent loop {p0['host_syncs']} syncs vs "
          f"{ph['scan_baseline']['host_syncs']} scan ({n} requests)")


def run_obs_only() -> None:
    """`--obs` / `make bench-obs`: the observability section alone —
    validates and prints, never rewrites BENCH_hotpath.json."""
    cfg = get_smoke_config(ARCH)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lat = LatencyModel(cfg, TPU_V5E)
    wl = sharegpt_style_trace(cfg, 50)
    obs = observability_section(model, params, lat, wl)
    print(json.dumps(obs, indent=2))
    _gate_observability(obs)
    print(f"OK: observability overhead {obs['overhead_pct']}% "
          f"<= {OBS_OVERHEAD_GATE_PCT}% gate")


def main() -> None:
    if "--obs" in sys.argv[1:]:
        run_obs_only()
        return
    if "--scale" in sys.argv[1:]:
        run_scale(smoke="--smoke" in sys.argv[1:])
        return
    if "--physical" in sys.argv[1:]:
        run_physical(smoke="--smoke" in sys.argv[1:])
        return
    rows = run(quick=True)
    for r in rows:
        print(r)
    print(validate(rows))
    by = {r["name"]: r for r in rows}
    s = by["hotpath_summary"]
    # CI gate (make bench-hotpath): losslessness and the compile-count
    # bound are deterministic and absolute; the speed gate is >= legacy so
    # a loaded shared runner can't flake the job — the checked-in
    # BENCH_hotpath.json records the >= 2x target
    if not (s["lossless_exact"] and s["lossless_timing"]):
        raise SystemExit("hotpath losslessness gate failed")
    if not s["flips_documented"]:
        raise SystemExit(
            "token flip vs legacy exceeds the documented ulp tolerance "
            f"({FLIP_TOL}): real numerical divergence, not a near-tie")
    if by["hotpath_optimized"]["prefill_compiles"] >= \
            by["hotpath_legacy"]["prefill_compiles"]:
        raise SystemExit("bucketed prefill no longer bounds compile count")
    if s["speedup_warm"] < 1.0:
        raise SystemExit("optimized engine slower than legacy")
    # full observability section (run() just wrote it) carries the
    # reconciliation flags the CSV row elides
    _gate_observability(json.loads(OUT_JSON.read_text())["observability"])


if __name__ == "__main__":
    main()
