"""Shared benchmark harness: the paper's OPT-66B/4xA100 deployment point.

Every figure/table module calls `run_point` with its own knobs and derives
its metric from the returned SimResult. `quick=True` shrinks trace length
(CI-friendly); full-scale numbers are produced with defaults.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro.configs import get_config
from repro.core import (
    A40_4X,
    A100_4X,
    HardwareSpec,
    LatencyModel,
    SchedulerConfig,
    make_scheduler,
)
from repro.serving.simulator import ServingSimulator, SimConfig, SimResult
from repro.workload import make_workload

# The paper's primary deployment: OPT-66B, 4xA100-80G, fp16 weights 132 GB,
# ~153 GB usable for KV at 90% memory utilization => M ≈ 65k tokens.
MODEL = "opt-66b"
KV_CAPACITY = 65_000
QOE_THRESHOLD = 0.9          # §6.1 capacity metric


def latency_model(hw: HardwareSpec = A100_4X) -> LatencyModel:
    return LatencyModel(get_config(MODEL), hw)


def run_point(
    scheduler: str,
    rate: float,
    *,
    n: int = 1000,
    seed: int = 1,
    dataset: str = "sharegpt",
    arrival: str = "poisson",
    qoe_trace: str = "reading",
    hw: HardwareSpec = A100_4X,
    sched_cfg: Optional[SchedulerConfig] = None,
    kv_capacity: int = KV_CAPACITY,
    charge_overhead: bool = False,
    quick: bool = False,
    **sched_kw,
) -> SimResult:
    if quick:
        # must still reach the saturated steady state (queueing builds over
        # the trace); 800 requests is the smallest trace that does
        n = min(n, 800)
    lat = latency_model(hw)
    wl = make_workload(n, rate, seed=seed, dataset=dataset, arrival=arrival,
                       qoe_trace=qoe_trace)
    sched = make_scheduler(scheduler, kv_capacity, lat,
                           sched_cfg or SchedulerConfig(), **sched_kw)
    sim = ServingSimulator(sched, lat, SimConfig(
        kv_capacity_tokens=kv_capacity,
        charge_scheduler_overhead=charge_overhead,
    ))
    return sim.run(wl)


def metrics_row(res: SimResult) -> Dict[str, float]:
    t = res.ttfts()
    q = res.qoes()
    return {
        "avg_qoe": res.avg_qoe(),
        "qoe_p10": float(np.percentile(q, 10)),
        "qoe_p50": float(np.percentile(q, 50)),
        "qoe_p90": float(np.percentile(q, 90)),
        "ttft_p50": float(np.percentile(t, 50)),
        "ttft_p90": float(np.percentile(t, 90)),
        "tds_p50": float(np.median(res.tds())),
        "throughput": res.throughput(),
        "preempt_freq": res.preemption_freq(),
        "norm_latency_p50": float(np.median(res.normalized_latencies())),
    }


def capacity_at_threshold(rates, avg_qoes, threshold=QOE_THRESHOLD) -> float:
    """Max request rate sustaining avg QoE >= threshold (linear interp)."""
    cap = 0.0
    for i, (r, q) in enumerate(zip(rates, avg_qoes)):
        if q >= threshold:
            cap = r
        elif i > 0 and avg_qoes[i - 1] >= threshold:
            r0, q0 = rates[i - 1], avg_qoes[i - 1]
            cap = r0 + (r - r0) * (q0 - threshold) / max(q0 - q, 1e-9)
            break
    return cap


def timed(fn, *a, **kw):
    t0 = time.perf_counter()
    out = fn(*a, **kw)
    return out, (time.perf_counter() - t0) * 1e6   # us
