"""Fig. 13 — preemption frequency per request stays low (<= ~0.5 at
reasonable QoE, bounded by ~k-1 under k-fold overload; §4.2 #4, §6.2.3)."""
from __future__ import annotations

from benchmarks.common import run_point

RATES = (2.4, 3.0, 3.6, 4.2)


def run(quick: bool = False):
    rows = []
    for rate in (RATES[:3] if quick else RATES):
        for sched in ("andes", "round_robin"):
            res = run_point(sched, rate, quick=quick)
            rows.append({
                "name": f"fig13/{sched}/rate={rate}",
                "preempt_per_req": round(res.preemption_freq(), 3),
                "avg_qoe": round(res.avg_qoe(), 3),
            })
    return rows


def validate(rows) -> str:
    andes = [r for r in rows if "/andes/" in r["name"]]
    ok = all(r["preempt_per_req"] <= 1.3 for r in andes)
    return f"Andes preemptions/request <= ~1 across rates: {ok}"


if __name__ == "__main__":
    for r in run():
        print(r)
