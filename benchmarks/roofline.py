"""Roofline analysis (deliverable g): render the per-(arch x shape x mesh)
table from the dry-run JSONs in experiments/dryrun/.

  compute    = HLO_FLOPs(per dev)  / peak_FLOPs(chip)
  memory     = HLO_bytes(per dev)  / HBM_bw(chip)
  collective = coll_bytes(per dev) / link_bw(chip)

plus MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (serve) and the
useful-compute ratio MODEL_FLOPS / (HLO_FLOPs x chips), which exposes remat
recompute and dispatch/replication waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_results(tag: str = "pod") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{tag}.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def summarize(r: Dict) -> Dict:
    rf = r["roofline"]
    total = rf["compute_s"] + rf["memory_s"] + rf["collective_s"]
    return {
        "arch": r["arch"],
        "shape": r["shape"],
        "mesh": r["mesh"],
        "compute_s": rf["compute_s"],
        "memory_s": rf["memory_s"],
        "collective_s": rf["collective_s"],
        "dominant": rf["dominant"].replace("_s", ""),
        "useful_flops_ratio": rf["useful_flops_ratio"],
        "bytes_per_dev_gb": (r["memory"]["argument_bytes"]
                             + r["memory"]["temp_bytes"]
                             + r["memory"]["output_bytes"]) / 1e9,
        "step_lower_bound_s": max(rf["compute_s"], rf["memory_s"],
                                  rf["collective_s"]),
        "balance": rf["compute_s"] / total if total else 0.0,
    }


def table(tag: str = "pod") -> List[Dict]:
    return [summarize(r) for r in load_results(tag)]


def render(tag: str = "pod") -> str:
    rows = table(tag)
    if not rows:
        return "(no dry-run artifacts; run python -m repro.launch.dryrun --all)"
    hdr = (f"{'arch':24s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
           f"{'collect':>10s} {'dominant':>10s} {'useful':>7s} {'GB/dev':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} "
            f"{r['compute_s']:10.3e} {r['memory_s']:10.3e} "
            f"{r['collective_s']:10.3e} {r['dominant']:>10s} "
            f"{r['useful_flops_ratio']:7.2f} {r['bytes_per_dev_gb']:8.1f}"
        )
    return "\n".join(lines)


def run(quick: bool = False):
    rows = []
    for r in table("pod"):
        rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}",
            "dominant": r["dominant"],
            "bound_s": round(r["step_lower_bound_s"], 4),
            "useful": round(r["useful_flops_ratio"], 2),
        })
    return rows


def validate(rows) -> str:
    n = len(rows)
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    return f"{n}/40 combos analyzed; dominant terms: {doms}"


if __name__ == "__main__":
    print(render("pod"))
    print()
    print(render("multipod"))
