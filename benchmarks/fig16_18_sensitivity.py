"""Figs. 16-18 — sensitivity: preemption cap P, horizon Δt, greedy vs DP
solver (with the DP's real host-side solve time charged to the clock)."""
from __future__ import annotations

from repro.core import SchedulerConfig

from benchmarks.common import run_point

RATE = 3.3


def run(quick: bool = False):
    rows = []
    # Fig. 16: preemption frequency cap P
    for p in (0.0, 0.2, 0.4, 1.0, 2.0):
        res = run_point("andes", RATE, quick=quick,
                        sched_cfg=SchedulerConfig(preemption_cap=p))
        rows.append({
            "name": f"fig16/P={p}",
            "avg_qoe": round(res.avg_qoe(), 3),
            "throughput": round(res.throughput(), 1),
        })
    # Fig. 17: prediction horizon Δt
    for dt in (10.0, 50.0, 100.0, 200.0, 400.0):
        res = run_point("andes", RATE, quick=quick,
                        sched_cfg=SchedulerConfig(delta_t=dt))
        rows.append({
            "name": f"fig17/dt={dt}",
            "avg_qoe": round(res.avg_qoe(), 3),
        })
    # Fig. 18: greedy vs DP (charge real solver wall time to the sim clock)
    for solver in ("andes", "andes_dp"):
        res = run_point(solver, RATE, n=300, quick=quick,
                        charge_overhead=True,
                        sched_cfg=SchedulerConfig(num_batch_candidates=4))
        rows.append({
            "name": f"fig18/{solver}",
            "avg_qoe": round(res.avg_qoe(), 3),
        })
    return rows


def validate(rows) -> str:
    d = {r["name"]: r for r in rows}
    p_flat = abs(d["fig16/P=1.0"]["avg_qoe"] - d["fig16/P=0.4"]["avg_qoe"]) < 0.05
    dt_flat = abs(d["fig17/dt=400.0"]["avg_qoe"] - d["fig17/dt=50.0"]["avg_qoe"]) < 0.05
    greedy_ge_dp = d["fig18/andes"]["avg_qoe"] >= d["fig18/andes_dp"]["avg_qoe"] - 0.02
    return (f"QoE flat for P>=0.4: {p_flat}; insensitive to dt>=50: {dt_flat}; "
            f"greedy >= DP end-to-end (DP overhead): {greedy_ge_dp}")


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print(validate(rows))
