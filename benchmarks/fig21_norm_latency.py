"""Fig. 21 (Appendix E) — normalized latency (end-to-end latency / output
length): comparable at low rates, much lower for Andes at high rates."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_point

RATES = (2.4, 3.2, 4.0, 4.8)


def run(quick: bool = False):
    rows = []
    for rate in RATES:   # the Andes win shows at the high-rate end
        vals = {}
        for sched in ("fcfs", "andes"):
            res = run_point(sched, rate, n=1500 if quick else 2000, quick=False)
            vals[sched] = float(np.median(res.normalized_latencies()))
        rows.append({
            "name": f"fig21/rate={rate}",
            "norm_lat_fcfs_s": round(vals["fcfs"], 3),
            "norm_lat_andes_s": round(vals["andes"], 3),
        })
    return rows


def validate(rows) -> str:
    last = rows[-1]
    return (f"at highest rate Andes normalized latency "
            f"{last['norm_lat_andes_s']}s <= FCFS {last['norm_lat_fcfs_s']}s: "
            f"{last['norm_lat_andes_s'] <= last['norm_lat_fcfs_s'] * 1.05}")


if __name__ == "__main__":
    for r in run():
        print(r)
