"""Fig. 15 — robustness: (a) weaker hardware (4xA40), (b) bursty Gamma
arrivals (cv=3), (c) voice-chat QoE trace (slower TDS, ~2x headroom)."""
from __future__ import annotations

from benchmarks.common import A40_4X, capacity_at_threshold, run_point


def _sweep(tag, rates, quick, **kw):
    rows, curves = [], {}
    for sched in ("fcfs", "andes"):
        curves[sched] = []
        for rate in rates:
            res = run_point(sched, rate, quick=quick, **kw)
            curves[sched].append(res.avg_qoe())
            rows.append({
                "name": f"fig15/{tag}/{sched}/rate={rate}",
                "avg_qoe": round(res.avg_qoe(), 3),
            })
    caps = {s: capacity_at_threshold(rates, c) for s, c in curves.items()}
    gain = max(a / max(f, 1e-9)
               for a, f in zip(curves["andes"], curves["fcfs"]))
    rows.append({
        "name": f"fig15/{tag}/derived",
        "capacity_ratio": round(caps["andes"] / max(caps["fcfs"], 1e-9), 2),
        "max_qoe_gain": round(gain, 2),
    })
    return rows


def run(quick: bool = False):
    rows = []
    # (a) weaker GPU: lower gen-speed headroom => smaller but real gains
    rows += _sweep("a40", (0.6, 0.9, 1.2, 1.5, 1.8), quick, hw=A40_4X)
    # (b) bursty arrivals
    rows += _sweep("gamma", (2.0, 2.6, 3.2, 3.8, 4.4), quick, arrival="gamma")
    # (c) voice QoE trace: slower digest speed => ~2x theoretical headroom
    rows += _sweep("voice", (3.0, 3.8, 4.6, 5.4, 6.2), quick,
                   qoe_trace="voice")
    return rows


def validate(rows) -> str:
    d = {r["name"]: r for r in rows if r["name"].endswith("derived")}
    return (
        f"a40 capacity ratio {d['fig15/a40/derived']['capacity_ratio']}x "
        f"(paper ~1.1x); gamma {d['fig15/gamma/derived']['capacity_ratio']}x "
        f"(paper ~1.3x); voice {d['fig15/voice/derived']['capacity_ratio']}x "
        f"(paper ~2x)"
    )


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print(validate(rows))
