"""Fig. 10 — average QoE vs request rate on ShareGPT (FCFS / RR / Andes),
plus the derived capacity-at-0.9 ratio (§6.2.2: 1.2-1.6x)."""
from __future__ import annotations

from benchmarks.common import capacity_at_threshold, metrics_row, run_point

RATES = (2.4, 3.0, 3.6, 4.2, 4.8, 5.4)
SCHEDS = ("fcfs", "round_robin", "andes")


def run(quick: bool = False, dataset: str = "sharegpt"):
    rates = RATES   # full grid even in quick mode (capacity needs the ends)
    rows = []
    curves = {s: [] for s in SCHEDS}
    for sched in SCHEDS:
        for rate in rates:
            res = run_point(sched, rate, dataset=dataset, quick=quick)
            m = metrics_row(res)
            curves[sched].append(m["avg_qoe"])
            rows.append({
                "name": f"fig10/{dataset}/{sched}/rate={rate}",
                "avg_qoe": round(m["avg_qoe"], 3),
                "ttft_p90_s": round(m["ttft_p90"], 2),
            })
    # sustained-overload point (paper's traces are long enough that the
    # backlog reaches steady state; gain peaks here)
    sus = {}
    for sched in ("fcfs", "andes"):
        res = run_point(sched, 4.6, n=800 if quick else 2000,
                        dataset=dataset, quick=False)
        sus[sched] = res.avg_qoe()
    rows.append({
        "name": f"fig10/{dataset}/sustained@4.6",
        "fcfs": round(sus["fcfs"], 3), "andes": round(sus["andes"], 3),
        "gain": round(sus["andes"] / max(sus["fcfs"], 1e-9), 2),
    })
    caps = {s: capacity_at_threshold(rates, curves[s]) for s in SCHEDS}
    qoe_gain = max(
        [a / max(f, 1e-9) for a, f in zip(curves["andes"], curves["fcfs"])]
        + [sus["andes"] / max(sus["fcfs"], 1e-9)]
    )
    rows.append({
        "name": f"fig10/{dataset}/derived",
        "capacity_fcfs": round(caps["fcfs"], 2),
        "capacity_andes": round(caps["andes"], 2),
        "capacity_ratio": round(caps["andes"] / max(caps["fcfs"], 1e-9), 2),
        "max_qoe_gain": round(qoe_gain, 2),
    })
    return rows


def validate(rows) -> str:
    d = rows[-1]
    return (f"capacity ratio {d['capacity_ratio']}x (paper: 1.2-1.6x); "
            f"max avg-QoE gain {d['max_qoe_gain']}x under sustained overload "
            f"(paper: up to 3.1x at its most constrained setup)")


if __name__ == "__main__":
    for r in run():
        print(r)
