"""Appendix A — alternative scheduling objectives: max-min QoE (Eq. 6) and
perfect-QoE count (Eq. 7), compared with the default avg-QoE (Eq. 2)."""
from __future__ import annotations

import numpy as np

from repro.core import SchedulerConfig

from benchmarks.common import run_point

RATE = 4.2


def run(quick: bool = False):
    rows = []
    fcfs = run_point("fcfs", RATE, quick=quick)
    qf = fcfs.qoes()
    rows.append({
        "name": "appendixA/fcfs-baseline",
        "avg_qoe": round(fcfs.avg_qoe(), 3),
        "qoe_p5": round(float(np.percentile(qf, 5)), 3),
        "perfect_pct": round(100 * float(np.mean(qf >= 0.99)), 1),
    })
    for objective in ("avg_qoe", "max_min_qoe", "perfect_count"):
        res = run_point("andes", RATE, quick=quick,
                        sched_cfg=SchedulerConfig(objective=objective))
        q = res.qoes()
        rows.append({
            "name": f"appendixA/{objective}",
            "avg_qoe": round(res.avg_qoe(), 3),
            "qoe_p5": round(float(np.percentile(q, 5)), 3),
            "perfect_pct": round(100 * float(np.mean(q >= 0.99)), 1),
        })
    return rows


def validate(rows) -> str:
    d = {r["name"].split("/")[1]: r for r in rows}
    floor_up = d["max_min_qoe"]["qoe_p5"] >= d["fcfs-baseline"]["qoe_p5"] + 0.05
    pc = d["perfect_count"]["perfect_pct"] >= d["avg_qoe"]["perfect_pct"] - 1.0
    return (f"every objective beats the FCFS floor (max-min p5 "
            f"{d['max_min_qoe']['qoe_p5']} vs {d['fcfs-baseline']['qoe_p5']}): "
            f"{floor_up}; perfect-count share {d['perfect_count']['perfect_pct']}% "
            f">= avg-objective {d['avg_qoe']['perfect_pct']}%: {pc}. Note: past "
            f"capacity, max-min trades average for stragglers (many of them "
            f"unsalvageable) — the avg-QoE objective dominates there, which is "
            f"why the paper defaults to it.")


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print(validate(rows))
